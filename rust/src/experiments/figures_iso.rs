//! Figure generators: iso-capacity (Figs 4–6) and iso-area (Figs 8–9).

use crate::analysis::batch::{batch_sweep, BATCHES};
use crate::analysis::isoarea::{iso_area, mean_edp_reduction};
use crate::analysis::isocapacity::{headline_edp_reduction, iso_capacity};
use crate::engine::Engine;
use crate::util::csv::Csv;
use crate::util::stats::mean;
use crate::util::table::{fnum, Table};
use crate::workloads::memstats::Phase;
use super::{filter_rows, Output, Params};

/// Fig 4: iso-capacity dynamic + leakage energy, normalized to SRAM.
pub fn fig4(engine: &Engine, params: &Params) -> Output {
    let rows = filter_rows(iso_capacity(engine), params, |r| r.label.as_str());
    let mut t = Table::new(
        "Fig 4: iso-capacity (3MB) dynamic and leakage energy vs SRAM",
        &["workload", "dyn STT", "dyn SOT", "leak STT", "leak SOT"],
    );
    let mut csv = Csv::new(&["workload", "dyn_stt", "dyn_sot", "leak_stt", "leak_sot"]);
    for r in &rows {
        t.row(&[
            r.label.clone(),
            fnum(r.dynamic[0], 2),
            fnum(r.dynamic[1], 2),
            fnum(r.leakage[0], 3),
            fnum(r.leakage[1], 3),
        ]);
        csv.rowd(&[&r.label, &r.dynamic[0], &r.dynamic[1], &r.leakage[0], &r.leakage[1]]);
    }
    let dyn_stt = mean(&rows.iter().map(|r| r.dynamic[0]).collect::<Vec<_>>());
    let dyn_sot = mean(&rows.iter().map(|r| r.dynamic[1]).collect::<Vec<_>>());
    let leak_stt = mean(&rows.iter().map(|r| 1.0 / r.leakage[0]).collect::<Vec<_>>());
    let leak_sot = mean(&rows.iter().map(|r| 1.0 / r.leakage[1]).collect::<Vec<_>>());
    Output::default().table(t).csv("fig4_isocap_energy", csv).headline(format!(
        "Fig 4: dyn energy STT {:.1}x / SOT {:.1}x SRAM (paper 2.2/1.3); leak advantage {:.1}x/{:.1}x (paper 6.3/10)",
        dyn_stt, dyn_sot, leak_stt, leak_sot
    ))
}

/// Fig 5: iso-capacity total energy and EDP (with DRAM), normalized.
pub fn fig5(engine: &Engine, params: &Params) -> Output {
    let rows = filter_rows(iso_capacity(engine), params, |r| r.label.as_str());
    let mut t = Table::new(
        "Fig 5: iso-capacity (3MB) energy and EDP vs SRAM (EDP incl. DRAM)",
        &["workload", "energy STT", "energy SOT", "EDP STT", "EDP SOT"],
    );
    let mut csv = Csv::new(&["workload", "energy_stt", "energy_sot", "edp_stt", "edp_sot"]);
    for r in &rows {
        t.row(&[
            r.label.clone(),
            fnum(r.energy[0], 3),
            fnum(r.energy[1], 3),
            fnum(r.edp[0], 3),
            fnum(r.edp[1], 3),
        ]);
        csv.rowd(&[&r.label, &r.energy[0], &r.energy[1], &r.edp[0], &r.edp[1]]);
    }
    let [stt, sot] = headline_edp_reduction(&rows);
    let e_stt = mean(&rows.iter().map(|r| 1.0 / r.energy[0]).collect::<Vec<_>>());
    let e_sot = mean(&rows.iter().map(|r| 1.0 / r.energy[1]).collect::<Vec<_>>());
    Output::default().table(t).csv("fig5_isocap_edp", csv).headline(format!(
        "Fig 5: energy reduction {:.1}x/{:.1}x avg (paper 5.3/8.6); EDP reduction up to {:.1}x/{:.1}x (paper 3.8/4.7)",
        e_stt, e_sot, stt, sot
    ))
}

/// Fig 6: batch-size impact on EDP, AlexNet training (top) and
/// inference (bottom).
pub fn fig6(engine: &Engine, params: &Params) -> Output {
    let batches = params.batches_or(&BATCHES);
    let mut out = Output::default();
    let mut headline_parts = Vec::new();
    for (phase, tag) in [(Phase::Training, "training"), (Phase::Inference, "inference")] {
        let sweep = batch_sweep(engine, phase, &batches);
        let mut t = Table::new(
            format!("Fig 6 ({tag}): AlexNet EDP vs SRAM across batch sizes"),
            &["batch", "EDP STT", "EDP SOT", "reduction STT", "reduction SOT"],
        );
        let mut csv = Csv::new(&["batch", "edp_stt", "edp_sot"]);
        for p in &sweep {
            t.row(&[
                p.batch.to_string(),
                fnum(p.edp_norm[0], 3),
                fnum(p.edp_norm[1], 3),
                fnum(1.0 / p.edp_norm[0], 2),
                fnum(1.0 / p.edp_norm[1], 2),
            ]);
            csv.rowd(&[&p.batch, &p.edp_norm[0], &p.edp_norm[1]]);
        }
        headline_parts.push(format!(
            "{tag}: STT {:.1}x..{:.1}x, SOT {:.1}x..{:.1}x",
            1.0 / sweep.first().unwrap().edp_norm[0],
            1.0 / sweep.last().unwrap().edp_norm[0],
            1.0 / sweep.first().unwrap().edp_norm[1],
            1.0 / sweep.last().unwrap().edp_norm[1],
        ));
        out = out.table(t).csv(&format!("fig6_batch_{tag}"), csv);
    }
    out.headline(format!(
        "Fig 6: {} (paper: training STT 2.3->4.6x, SOT 7.2-7.6x; inference STT 4.1-5.4x, SOT 7.1-7.3x)",
        headline_parts.join("; ")
    ))
}

/// Fig 8: iso-area dynamic + leakage energy, normalized to SRAM.
pub fn fig8(engine: &Engine, params: &Params) -> Output {
    let rows = filter_rows(iso_area(engine), params, |r| r.label.as_str());
    let mut t = Table::new(
        "Fig 8: iso-area (STT 7MB / SOT 10MB) dynamic and leakage energy vs SRAM",
        &["workload", "dyn STT", "dyn SOT", "leak STT", "leak SOT"],
    );
    let mut csv = Csv::new(&["workload", "dyn_stt", "dyn_sot", "leak_stt", "leak_sot"]);
    for r in &rows {
        t.row(&[
            r.label.clone(),
            fnum(r.dynamic[0], 2),
            fnum(r.dynamic[1], 2),
            fnum(r.leakage[0], 3),
            fnum(r.leakage[1], 3),
        ]);
        csv.rowd(&[&r.label, &r.dynamic[0], &r.dynamic[1], &r.leakage[0], &r.leakage[1]]);
    }
    let dyn_stt = mean(&rows.iter().map(|r| r.dynamic[0]).collect::<Vec<_>>());
    let dyn_sot = mean(&rows.iter().map(|r| r.dynamic[1]).collect::<Vec<_>>());
    let leak_stt = mean(&rows.iter().map(|r| 1.0 / r.leakage[0]).collect::<Vec<_>>());
    let leak_sot = mean(&rows.iter().map(|r| 1.0 / r.leakage[1]).collect::<Vec<_>>());
    Output::default().table(t).csv("fig8_isoarea_energy", csv).headline(format!(
        "Fig 8: dyn energy STT {:.1}x / SOT {:.1}x SRAM (paper 2.5/1.5); leak advantage {:.1}x/{:.1}x (paper 2.2/2.3)",
        dyn_stt, dyn_sot, leak_stt, leak_sot
    ))
}

/// Fig 9: iso-area EDP without (top) and with (bottom) DRAM.
pub fn fig9(engine: &Engine, params: &Params) -> Output {
    let rows = filter_rows(iso_area(engine), params, |r| r.label.as_str());
    let mut t = Table::new(
        "Fig 9: iso-area EDP vs SRAM, without and with DRAM",
        &["workload", "EDP STT (no DRAM)", "EDP SOT (no DRAM)", "EDP STT (+DRAM)", "EDP SOT (+DRAM)"],
    );
    let mut csv = Csv::new(&["workload", "edp_stt_cache", "edp_sot_cache", "edp_stt_dram", "edp_sot_dram"]);
    for r in &rows {
        t.row(&[
            r.label.clone(),
            fnum(r.edp_cache[0], 3),
            fnum(r.edp_cache[1], 3),
            fnum(r.edp_dram[0], 3),
            fnum(r.edp_dram[1], 3),
        ]);
        csv.rowd(&[&r.label, &r.edp_cache[0], &r.edp_cache[1], &r.edp_dram[0], &r.edp_dram[1]]);
    }
    let [stt, sot] = mean_edp_reduction(&rows);
    Output::default().table(t).csv("fig9_isoarea_edp", csv).headline(format!(
        "Fig 9: iso-area EDP reduction with DRAM {:.1}x/{:.1}x avg (paper 2.0/2.3)",
        stt, sot
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(f: fn(&Engine, &Params) -> Output) -> Output {
        f(Engine::shared(), &Params::default())
    }

    #[test]
    fn fig4_and_fig5_cover_the_suite() {
        assert_eq!(run(fig4).tables[0].len(), 13);
        assert_eq!(run(fig5).tables[0].len(), 13);
    }

    #[test]
    fn fig6_emits_both_phases() {
        let out = run(fig6);
        assert_eq!(out.tables.len(), 2);
        assert_eq!(out.csvs.len(), 2);
        assert_eq!(out.tables[0].len(), BATCHES.len());
    }

    #[test]
    fn fig6_custom_batch_grid() {
        let params = Params { batches: Some(vec![1, 16]), ..Params::default() };
        let out = fig6(Engine::shared(), &params);
        assert_eq!(out.tables[0].len(), 2);
    }

    #[test]
    fn fig4_network_filter_narrows_rows() {
        let params = Params { networks: Some(vec!["vgg16".into()]), ..Params::default() };
        let out = fig4(Engine::shared(), &params);
        assert_eq!(out.tables[0].len(), 2, "VGG-16-I and VGG-16-T");
    }

    #[test]
    fn fig9_mram_wins_iso_area_edp_both_ways() {
        // Paper: MRAM wins iso-area EDP once DRAM is counted (its
        // cache-only win is marginal, ~1.2×). In our substrate the MRAM
        // iso-area caches already win at the cache level, so DRAM
        // inclusion only has to preserve the win — the deviation is
        // documented in EXPERIMENTS.md §Fig 9.
        let rows = iso_area(Engine::shared());
        let with: f64 = mean(&rows.iter().map(|r| r.edp_dram[1]).collect::<Vec<_>>());
        let without: f64 = mean(&rows.iter().map(|r| r.edp_cache[1]).collect::<Vec<_>>());
        assert!(with < 1.0, "SOT iso-area EDP with DRAM must beat SRAM: {with}");
        assert!(without < 1.0, "and without DRAM too: {without}");
        // DRAM inclusion changes the picture by at most ~35%.
        assert!((with / without - 1.0).abs() < 0.35);
        assert_eq!(run(fig9).tables[0].len(), 13);
    }
}
