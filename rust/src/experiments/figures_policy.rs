//! figWP: write-policy sensitivity — per-network EDP for SRAM/STT/SOT
//! under each L2 write policy (write-back, write-through, write-bypass).
//!
//! This is the experiment the policy-generic hierarchy exists for. NVM
//! write transactions are the expensive ones (STT write energy is ~5-10×
//! its read energy at the tuned 3MB designs), so *which writes reach the
//! array* is a first-order knob the paper's fixed write-back simulator
//! could not turn. For every Fig 7 network all three policies ride one
//! multi-configuration replay ([`simulate_group`]): the trace is
//! compiled, partitioned, and decoded once, and each block probes the
//! three hierarchies — bit-identical to three standalone sharded replays
//! at a third of the decode work. The resulting transaction counters roll
//! up through the §4 model against each technology's EDAP-tuned 3MB
//! design, and the table reports EDP normalized — per technology — to
//! that technology's write-back baseline. `--replacement` / `--l1` /
//! `--warmup-frac` set the shared base configuration; `--networks`
//! narrows the suite.

use super::figures_scale::{fig7_selected_suite, fig7_suite};
use super::{Output, Params};
use crate::analysis::model;
use crate::engine::Engine;
use crate::gpusim::{
    net_trace, simulate_group, Access, CacheConfig, GpuConfig, ReplayConfig, WritePolicy,
};
use crate::nvsim::cache::CachePpa;
use crate::util::csv::Csv;
use crate::util::pool::{par_map, split_threads};
use crate::util::table::{fnum, Table};
use crate::workloads::ir::NetIr;
use crate::workloads::memstats::MemStats;

/// The figWP technology columns, in paper order.
const TECHS: [&str; 3] = ["sram", "stt", "sot"];

/// One simulated (network, policy) cell.
#[derive(Debug, Clone)]
struct WpRow {
    net: String,
    batch: u64,
    policy: WritePolicy,
    stats: MemStats,
}

/// Replay every suite trace under every write policy: one materialized
/// trace per network, one grouped decode-once replay driving all three
/// policy hierarchies (bit-identical per member to a standalone
/// set-sharded replay).
fn simulate_suite(
    suite: &[(NetIr, u64)],
    base: CacheConfig,
    warmup_frac: Option<f64>,
) -> Vec<WpRow> {
    let gpu = GpuConfig::gtx_1080_ti();
    // The per-net fan-out already fills the pool; split the shard budget
    // so net-parallelism × shard-parallelism stays ≈ the core count.
    let shards = split_threads(suite.len());
    let per_net: Vec<Vec<WpRow>> = par_map(suite, |(net, batch)| {
        let trace: Vec<Access> = net_trace(net, *batch).collect();
        let warmup = match warmup_frac {
            None => 0,
            Some(f) => (f * trace.len() as f64) as u64,
        };
        let configs: Vec<ReplayConfig> = WritePolicy::ALL
            .iter()
            .map(|&policy| ReplayConfig::new(gpu.clone(), CacheConfig { write: policy, ..base }))
            .collect();
        let sims = simulate_group(trace.into_iter(), &configs, warmup, shards);
        WritePolicy::ALL
            .iter()
            .zip(sims)
            .map(|(&policy, sim)| WpRow {
                net: net.name.clone(),
                batch: *batch,
                policy,
                stats: model::stats_from_sim(&sim, gpu.l2_line),
            })
            .collect()
    });
    per_net.into_iter().flatten().collect()
}

/// The default-parameter simulations, memoized process-wide (the figure
/// is invoked from tests and registry runs; the traces are deterministic,
/// so each (network, policy) replay runs at most once per process).
fn default_sims() -> &'static [WpRow] {
    static SIMS: std::sync::OnceLock<Vec<WpRow>> = std::sync::OnceLock::new();
    SIMS.get_or_init(|| simulate_suite(&fig7_suite(), CacheConfig::default(), None))
}

/// figWP generator: write-policy sensitivity of per-network EDP.
/// `--write-policy` is deliberately ignored (the figure sweeps all three
/// policies itself); only the knobs that change the shared base
/// configuration defeat the memoized default run.
pub fn figwp(engine: &Engine, params: &Params) -> Output {
    let base = CacheConfig { write: WritePolicy::WriteBack, ..params.cache_config() };
    let is_default =
        params.networks.is_none() && base.is_default() && params.warmup_frac.is_none();
    let fresh;
    let rows: &[WpRow] = if is_default {
        default_sims()
    } else {
        let suite = fig7_selected_suite(engine, params);
        fresh = simulate_suite(&suite, base, params.warmup_frac);
        &fresh
    };

    // EDAP-tuned 3MB designs (the iso-capacity baseline of Fig 5).
    let gpu = GpuConfig::gtx_1080_ti();
    let ppas: Vec<CachePpa> = TECHS
        .iter()
        .map(|t| {
            engine
                .tuned(t, gpu.l2_bytes)
                .expect("builtin technologies tune at the 3MB baseline")
                .ppa
        })
        .collect();

    let edp = |row: &WpRow, tech_i: usize| -> f64 {
        model::evaluate(&ppas[tech_i], &row.stats).edp_with_dram()
    };
    // Per (net, tech): the write-back EDP that row's normalization uses.
    let wb_edp = |net: &str, tech_i: usize| -> f64 {
        rows.iter()
            .find(|r| r.net == net && r.policy == WritePolicy::WriteBack)
            .map(|r| edp(r, tech_i))
            .unwrap_or(f64::NAN)
    };

    let mut t = Table::new(
        "figWP: write-policy sensitivity at the 3MB L2 (EDP normalized to write-back per tech)",
        &[
            "network",
            "policy",
            "L2 wr (Mtx)",
            "DRAM wr (Mtx)",
            "EDP SRAM",
            "EDP STT",
            "EDP SOT",
        ],
    );
    let mut csv = Csv::new(&[
        "network",
        "batch",
        "policy",
        "l2_reads",
        "l2_writes",
        "dram_reads",
        "dram_writes",
        "edp_sram",
        "edp_stt",
        "edp_sot",
    ]);
    // Mean normalized EDP per (tech, policy) across networks — the
    // headline quantities.
    let nets: Vec<String> = {
        let mut seen = Vec::new();
        for r in rows {
            if !seen.contains(&r.net) {
                seen.push(r.net.clone());
            }
        }
        seen
    };
    let mut mean_rel = [[0.0f64; 3]; 3]; // [policy][tech]
    for row in rows {
        let rel: Vec<f64> = (0..3).map(|i| edp(row, i) / wb_edp(&row.net, i)).collect();
        let p_i = WritePolicy::ALL.iter().position(|&p| p == row.policy).expect("known policy");
        for (i, r) in rel.iter().enumerate() {
            mean_rel[p_i][i] += r / nets.len() as f64;
        }
        t.row(&[
            row.net.clone(),
            row.policy.name().to_string(),
            fnum(row.stats.l2_writes as f64 / 1e6, 2),
            fnum(row.stats.dram_writes as f64 / 1e6, 2),
            fnum(rel[0], 3),
            fnum(rel[1], 3),
            fnum(rel[2], 3),
        ]);
        csv.rowd(&[
            &row.net,
            &row.batch,
            &row.policy.name(),
            &row.stats.l2_reads,
            &row.stats.l2_writes,
            &row.stats.dram_reads,
            &row.stats.dram_writes,
            &edp(row, 0),
            &edp(row, 1),
            &edp(row, 2),
        ]);
    }

    let idx_of = |p: WritePolicy| WritePolicy::ALL.iter().position(|&x| x == p).expect("known");
    let byp = idx_of(WritePolicy::WriteBypass);
    let wt = idx_of(WritePolicy::WriteThrough);
    Output::default()
        .table(t)
        .csv("figwp_write_policy", csv)
        .headline(format!(
            "figWP ({} nets): write-bypass mean EDP x{:.2} (STT) / x{:.2} (SOT) / x{:.2} (SRAM) \
             vs write-back",
            nets.len(),
            mean_rel[byp][1],
            mean_rel[byp][2],
            mean_rel[byp][0],
        ))
        .headline(format!(
            "figWP: write-through mean EDP x{:.2} (STT) / x{:.2} (SRAM) vs write-back — \
             paper's fixed WB/WA simulator could not expose this axis",
            mean_rel[wt][1],
            mean_rel[wt][0],
        ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figwp_covers_suite_x_policies() {
        let out = figwp(Engine::shared(), &Params::default());
        let suite_len = fig7_suite().len();
        assert_eq!(out.tables[0].len(), suite_len * 3, "one row per (net, policy)");
        assert_eq!(out.csvs[0].0, "figwp_write_policy");
        assert_eq!(out.csvs[0].1.len(), suite_len * 3);
        assert!(out.headlines[0].contains("write-bypass"), "{}", out.headlines[0]);
    }

    #[test]
    fn figwp_narrowed_suite_and_base_config() {
        use crate::gpusim::Replacement;
        let params = Params {
            networks: Some(vec!["squeezenet".into()]),
            replacement: Some(Replacement::Srrip),
            warmup_frac: Some(0.2),
            ..Params::default()
        };
        let out = figwp(Engine::shared(), &params);
        assert_eq!(out.tables[0].len(), 3, "one net, three policies");
        let rendered = out.tables[0].render();
        assert!(rendered.contains("SqueezeNet"), "{rendered}");
        assert!(rendered.contains("bypass"), "{rendered}");
    }

    #[test]
    fn write_back_rows_normalize_to_one() {
        let out = figwp(Engine::shared(), &Params::default());
        // Every wb row's normalized EDP columns must render as 1.000.
        let rendered = out.tables[0].render();
        let wb_rows: Vec<&str> = rendered.lines().filter(|l| l.contains(" wb ")).collect();
        assert!(!wb_rows.is_empty());
        for row in wb_rows {
            assert!(row.matches("1.000").count() >= 3, "{row}");
        }
    }
}
