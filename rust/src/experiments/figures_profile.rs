//! Figure generators: Fig 1 (GPU L2 trend) and Fig 3 (R/W ratios).

use crate::engine::Engine;
use crate::util::csv::Csv;
use crate::util::table::{fnum, Table};
use crate::workloads::profiler::{ProfiledWorkload, Workload, PROFILE_L2};
use super::{Output, Params};

/// Public L2-capacity data behind the paper's Fig 1 (NVIDIA GeForce
/// flagships by generation, from the public GPU lists the paper cites).
pub const GPU_L2_TREND: [(&str, u32, f64); 8] = [
    ("GTX 580 (Fermi)", 2010, 0.75),
    ("GTX 680 (Kepler)", 2012, 0.5),
    ("GTX 780 Ti (Kepler)", 2013, 1.5),
    ("GTX 980 Ti (Maxwell)", 2015, 3.0),
    ("GTX 1080 Ti (Pascal)", 2017, 3.0),
    ("RTX 2080 Ti (Turing)", 2018, 5.5),
    ("Titan RTX (Turing)", 2018, 6.0),
    ("RTX 3090 (Ampere)", 2020, 6.0),
];

/// Fig 1: the L2 capacity trend motivating the scalability study.
pub fn fig1(_engine: &Engine, _params: &Params) -> Output {
    let mut t = Table::new("Fig 1: L2 cache capacity in recent NVIDIA GPUs", &["GPU", "year", "L2 (MB)"]);
    let mut csv = Csv::new(&["gpu", "year", "l2_mb"]);
    for (gpu, year, mb) in GPU_L2_TREND {
        t.row(&[gpu.to_string(), year.to_string(), fnum(mb, 2)]);
        csv.rowd(&[&gpu, &year, &mb]);
    }
    Output::default().table(t).csv("fig1_l2_trend", csv).headline(
        "Fig 1: flagship L2 grows 0.75MB (2010) -> 6MB (2020), the trend motivating NVM LLCs",
    )
}

/// Fig 3: L2 read/write transaction ratios across the workload suite.
/// Default params reproduce the paper's 13 rows byte-for-byte; with
/// `--networks` the row pool is the engine's *full* registry suite, so
/// the transformer/LSTM builtins and `--net-file` workloads join the
/// figure by display name *or* registry id (`vit_encoder` selects the
/// ViT-Enc rows). A filter matching nothing degrades gracefully to the
/// paper's 13 rows — the same artifact the no-filter default emits.
pub fn fig3(engine: &Engine, params: &Params) -> Output {
    let profiles: Vec<ProfiledWorkload> = if params.networks.is_none() {
        engine.profile_suite(PROFILE_L2)
    } else {
        let selected: Vec<ProfiledWorkload> = engine
            .profile_full_suite(PROFILE_L2)
            .into_iter()
            .filter(|p| {
                let id = match &p.workload {
                    Workload::Net { id, .. } => id.as_str(),
                    Workload::Hpcg(_) => "",
                };
                params.workload_selected(&p.label, id)
            })
            .collect();
        if selected.is_empty() {
            engine.profile_suite(PROFILE_L2)
        } else {
            selected
        }
    };
    let mut t = Table::new(
        "Fig 3: L2 read/write transaction ratio (nvprof substitute)",
        &["workload", "L2 reads", "L2 writes", "R/W ratio"],
    );
    let mut csv = Csv::new(&["workload", "l2_reads", "l2_writes", "ratio"]);
    let mut min = f64::INFINITY;
    let mut max = 0.0f64;
    for p in &profiles {
        let r = p.stats.rw_ratio();
        min = min.min(r);
        max = max.max(r);
        t.row(&[
            p.label.clone(),
            p.stats.l2_reads.to_string(),
            p.stats.l2_writes.to_string(),
            fnum(r, 2),
        ]);
        csv.rowd(&[&p.label, &p.stats.l2_reads, &p.stats.l2_writes, &r]);
    }
    Output::default().table(t).csv("fig3_rw_ratios", csv).headline(format!(
        "Fig 3: R/W ratio spans {:.1}..{:.1} across the suite (paper: 2..26)",
        min, max
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_trend_is_upward_overall() {
        let first = GPU_L2_TREND[0].2;
        let last = GPU_L2_TREND.last().unwrap().2;
        assert!(last > 4.0 * first);
        let out = fig1(Engine::shared(), &Params::default());
        assert_eq!(out.tables[0].len(), GPU_L2_TREND.len());
    }

    #[test]
    fn fig3_covers_thirteen_workloads() {
        let out = fig3(Engine::shared(), &Params::default());
        assert_eq!(out.tables[0].len(), 13);
        assert_eq!(out.csvs[0].1.len(), 13);
        assert!(out.headlines[0].contains("R/W ratio"));
    }

    #[test]
    fn fig3_network_filter_narrows_rows() {
        let params = Params { networks: Some(vec!["alexnet".into()]), ..Params::default() };
        let out = fig3(Engine::shared(), &params);
        assert_eq!(out.tables[0].len(), 2, "AlexNet-I and AlexNet-T");
    }

    #[test]
    fn fig3_reaches_registry_workloads_by_name() {
        // The open-workload path: transformer/LSTM builtins (and
        // `--net-file` nets) join the figure when named.
        let params = Params {
            networks: Some(vec!["gpt_block".into(), "lstm".into()]),
            ..Params::default()
        };
        let out = fig3(Engine::shared(), &params);
        assert_eq!(out.tables[0].len(), 4, "GPT-Block and LSTM, both phases");
        let rendered = out.tables[0].render();
        assert!(rendered.contains("GPT-Block-T"), "{rendered}");
        assert!(rendered.contains("LSTM-I"), "{rendered}");
        // Registry *ids* select too, even when the display name
        // normalizes differently (vit_encoder → "ViT-Enc-I/T").
        let by_id = Params { networks: Some(vec!["vit_encoder".into()]), ..Params::default() };
        let out = fig3(Engine::shared(), &by_id);
        assert_eq!(out.tables[0].len(), 2, "ViT rows by registry id");
        assert!(out.tables[0].render().contains("ViT-Enc-I"));
        // A typo degrades to the paper's 13 rows, not the 19-row pool —
        // the artifact schema matches the no-filter default.
        let typo = Params { networks: Some(vec!["alexnett".into()]), ..Params::default() };
        let out = fig3(Engine::shared(), &typo);
        assert_eq!(out.tables[0].len(), 13, "typo falls back to the paper suite");
    }
}
