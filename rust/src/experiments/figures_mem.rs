//! figMem: end-to-end EDP with the banked DRAM/HBM model behind the LLC.
//!
//! The paper's DRAM term is a flat per-transaction energy plus a
//! bandwidth-derived latency — capacity moves it only through the miss
//! count. This campaign replays the same miss stream through the banked
//! open-page model (see [`crate::membackend`]) and rolls the observed
//! row-buffer behavior into the §4 EDP: each cell (technology × L2
//! capacity) tunes the cache, simulates the workload trace with the DRAM
//! backend armed, and reports the row-class counters next to the
//! cache-only and end-to-end EDPs. The DRAM card's background power makes
//! the DRAM energy *technology-dependent* even at iso-capacity — a slower
//! cache holds the DIMM powered longer — which is exactly the coupling
//! the flat term cannot express. `--dram` swaps the default card (e.g.
//! `--dram stt` for the non-volatile DIMM with zero background power).

use super::figures_scale::fig7_selected_suite;
use super::{Output, Params};
use crate::engine::{Engine, Query};
use crate::membackend::{DramConfig, DramStats, MemBackendConfig};
use crate::util::csv::Csv;
use crate::util::table::Table;
use crate::workloads::memstats::Phase;
use crate::workloads::nets;
use crate::workloads::profiler::Workload;

const MB: u64 = 1 << 20;

/// The compared technologies, in paper order.
const TECHS: [&str; 3] = ["sram", "stt", "sot"];

/// Default capacity grid (MB). Small capacities keep the default run
/// quick (one trace simulation per capacity — the profile stage is
/// technology-independent, so the engine memo shares it across techs).
const CAPS_MB: [u64; 3] = [1, 2, 4];

/// One campaign cell.
#[derive(Debug, Clone)]
struct MemRow {
    tech: &'static str,
    net: String,
    batch: u64,
    cap_mb: u64,
    dram: DramStats,
    dram_energy: f64,
    dram_time: f64,
    edp_cache: f64,
    edp_total: f64,
}

/// The DRAM card the campaign runs: the `--dram` override when it names
/// one, the default DDR-class card otherwise (`--dram off` has nothing to
/// measure here, so it also falls back to the default card).
fn campaign_card(params: &Params) -> DramConfig {
    match &params.dram {
        Some(MemBackendConfig::Dram(card)) => *card,
        _ => DramConfig::default(),
    }
}

/// figMem generator: technology × capacity with the banked model armed.
/// Defaults replay SqueezeNet (batch 1) — the smallest trace in the suite
/// — and `--networks` widens to the fig7 selection.
pub fn figmem(engine: &Engine, params: &Params) -> Output {
    let card = campaign_card(params);
    let suite: Vec<(String, String, u64)> = if params.networks.is_none() {
        let net = nets::squeezenet();
        vec![(net.id.clone(), net.name.clone(), 1)]
    } else {
        fig7_selected_suite(engine, params)
            .into_iter()
            .map(|(net, batch)| (net.id.clone(), net.name.clone(), batch))
            .collect()
    };
    let caps = params.capacities_or(&CAPS_MB);

    // Pre-tune every (tech, capacity) on the engine's own parallelism so
    // pool workers only simulate and roll up.
    for tech in TECHS {
        for &mb in &caps {
            engine.tuned(tech, mb * MB).expect("builtin technologies tune at campaign capacities");
        }
    }

    let mut cells: Vec<(&'static str, usize, u64)> = Vec::new();
    for (n_i, _) in suite.iter().enumerate() {
        for tech in TECHS {
            for &mb in &caps {
                cells.push((tech, n_i, mb));
            }
        }
    }
    let queries: Vec<Query> = cells
        .iter()
        .map(|&(tech, n_i, cap_mb)| {
            let (id, _, batch) = &suite[n_i];
            Query::tune(tech, cap_mb * MB)
                .with_workload(Workload::net(id.clone(), Phase::Inference))
                .with_batch(*batch)
                .with_dram(MemBackendConfig::Dram(card))
        })
        .collect();
    // One batch call: `evaluate_many` groups each (net × batch)'s
    // distinct capacities into a decode-once multi-configuration replay,
    // and the technology-independent profile memo shares every replay
    // across the three techs.
    let rows: Vec<MemRow> = engine
        .evaluate_many(&queries)
        .into_iter()
        .zip(&cells)
        .map(|(res, &(tech, n_i, cap_mb))| {
            let (_, name, batch) = &suite[n_i];
            let w = res
                .expect("figMem queries evaluate on builtin techs")
                .workload
                .expect("query carried a workload");
            MemRow {
                tech,
                net: name.clone(),
                batch: *batch,
                cap_mb,
                dram: w.dram,
                dram_energy: w.rollup.dram_energy,
                dram_time: w.rollup.dram_time,
                edp_cache: w.rollup.edp_cache(),
                edp_total: w.rollup.edp_with_dram(),
            }
        })
        .collect();

    let mut t = Table::new(
        format!("figMem: end-to-end EDP behind a {} main memory", card_label(&card)),
        &[
            "tech",
            "network",
            "cap (MB)",
            "dram rd",
            "dram wr",
            "row hit%",
            "conflicts",
            "E_dram (J)",
            "t_dram (s)",
            "EDP cache",
            "EDP total",
        ],
    );
    let mut csv = Csv::new(&[
        "tech",
        "capacity_mb",
        "net",
        "batch",
        "dram_reads",
        "dram_writes",
        "row_hits",
        "row_misses",
        "row_conflicts",
        "row_hit_rate",
        "queue_excess",
        "dram_energy_j",
        "dram_time_s",
        "edp_cache",
        "edp_total",
    ]);
    for row in &rows {
        t.row(&[
            row.tech.to_string(),
            row.net.clone(),
            row.cap_mb.to_string(),
            row.dram.reads.to_string(),
            row.dram.writes.to_string(),
            format!("{:.1}", 100.0 * row.dram.row_hit_rate()),
            row.dram.row_conflicts.to_string(),
            format!("{:.3e}", row.dram_energy),
            format!("{:.3e}", row.dram_time),
            format!("{:.3e}", row.edp_cache),
            format!("{:.3e}", row.edp_total),
        ]);
        csv.rowd(&[
            &row.tech,
            &row.cap_mb,
            &row.net,
            &row.batch,
            &row.dram.reads,
            &row.dram.writes,
            &row.dram.row_hits,
            &row.dram.row_misses,
            &row.dram.row_conflicts,
            &row.dram.row_hit_rate(),
            &row.dram.queue_excess(),
            &row.dram_energy,
            &row.dram_time,
            &row.edp_cache,
            &row.edp_total,
        ]);
    }

    let top_cap = caps.iter().copied().max().unwrap_or(0);
    let find = |tech: &str| rows.iter().find(|r| r.tech == tech && r.cap_mb == top_cap);
    let mut out = Output::default();
    if let (Some(sram), Some(stt), Some(sot)) = (find("sram"), find("stt"), find("sot")) {
        out = out.headline(format!(
            "figMem ({} × b{}, {}): end-to-end EDP @{}MB — SRAM {:.3e}, STT {:.3e}, \
             SOT {:.3e} (cache-only {:.3e}/{:.3e}/{:.3e})",
            sram.net,
            sram.batch,
            card_label(&card),
            top_cap,
            sram.edp_total,
            stt.edp_total,
            sot.edp_total,
            sram.edp_cache,
            stt.edp_cache,
            sot.edp_cache,
        ));
        out = out.headline(format!(
            "figMem: {} DRAM reads / {} writes @{}MB, row-hit rate {:.1}% \
             ({} conflicts, queue excess {})",
            sram.dram.reads,
            sram.dram.writes,
            top_cap,
            100.0 * sram.dram.row_hit_rate(),
            sram.dram.row_conflicts,
            sram.dram.queue_excess(),
        ));
    }
    if out.headlines.is_empty() {
        out = out.headline(format!("figMem: {} campaign cells", rows.len()));
    }
    out.table(t).csv("figmem_end_to_end", csv)
}

/// Short card descriptor for the table title and headline
/// (`dram(c4r1b16 row2048)`).
fn card_label(card: &DramConfig) -> String {
    MemBackendConfig::Dram(*card).describe()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figmem_covers_tech_x_capacity_with_nonzero_dram_terms() {
        let params = Params { capacities_mb: Some(vec![1]), ..Params::default() };
        let out = figmem(Engine::shared(), &params);
        assert_eq!(out.tables[0].len(), TECHS.len(), "tech × cap rows");
        assert_eq!(out.csvs[0].0, "figmem_end_to_end");
        assert_eq!(out.csvs[0].1.len(), TECHS.len());
        assert!(!out.headlines.is_empty());
        let csv = out.csvs[0].1.to_string();
        let cell = |line: &str, i: usize| line.split(',').nth(i).unwrap().to_string();
        let lines: Vec<&str> = csv.lines().skip(1).collect();
        let sram = lines.iter().find(|l| l.starts_with("sram,1,")).unwrap();
        let sot = lines.iter().find(|l| l.starts_with("sot,1,")).unwrap();
        // The banked model observed traffic and the roll-up carries it.
        assert!(cell(sram, 4).parse::<u64>().unwrap() > 0, "dram reads: {csv}");
        let energy = |l: &str| cell(l, 11).parse::<f64>().unwrap();
        assert!(energy(sram) > 0.0, "{csv}");
        // The identical miss stream lands on identical device counters…
        for i in 4..=10 {
            assert_eq!(cell(sram, i), cell(sot, i), "col {i}: {csv}");
        }
        // …but the background-power term makes the DRAM energy follow the
        // cache's time — the technology dependence the flat term lacks.
        assert_ne!(energy(sram), energy(sot), "{csv}");
    }

    #[test]
    fn figmem_is_deterministic_and_honors_the_dram_override() {
        let params = Params { capacities_mb: Some(vec![1]), ..Params::default() };
        let a = figmem(Engine::shared(), &params);
        let b = figmem(Engine::shared(), &params);
        assert_eq!(a.csvs[0].1.to_string(), b.csvs[0].1.to_string());
        // A zero-background-power card (the STT DIMM) collapses the
        // technology dependence at iso-capacity but keeps the access term.
        let nv = Params {
            capacities_mb: Some(vec![1]),
            dram: Some(MemBackendConfig::Dram(DramConfig::stt_dimm())),
            ..Params::default()
        };
        let out = figmem(Engine::shared(), &nv);
        let csv = out.csvs[0].1.to_string();
        let cell = |line: &str, i: usize| line.split(',').nth(i).unwrap().to_string();
        let lines: Vec<&str> = csv.lines().skip(1).collect();
        let sram = lines.iter().find(|l| l.starts_with("sram,1,")).unwrap();
        let sot = lines.iter().find(|l| l.starts_with("sot,1,")).unwrap();
        let energy = |l: &str| cell(l, 11).parse::<f64>().unwrap();
        assert!(energy(sram) > 0.0);
        assert_eq!(energy(sram), energy(sot), "no leakage → no tech coupling: {csv}");
        // And the card actually changed the numbers vs the default run.
        assert_ne!(energy(sram), {
            let l = a.csvs[0].1.to_string();
            let line = l.lines().skip(1).find(|l| l.starts_with("sram,1,")).unwrap().to_string();
            cell(&line, 11).parse::<f64>().unwrap()
        });
    }
}
