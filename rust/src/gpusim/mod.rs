//! Trace-driven GPU memory-hierarchy simulator (paper §3.4 → Fig 7) —
//! the stand-in for the extended GPGPU-Sim + DarkNet stack.
//!
//! The paper's iso-area question is: *if the L2 were bigger (same area,
//! denser MRAM cells), how much DRAM traffic disappears?* GPGPU-Sim
//! answers it by simulating AlexNet at L2 capacities from 3MB (the real
//! GTX 1080 Ti) doubled up to 24MB. Here:
//!
//! * [`config`] — the Table 4 GPU configuration.
//! * [`cache`] — a set-associative write-back cache with true LRU.
//! * [`trace`] — streaming address-trace compilation from the workload
//!   IR (im2col + tiled sgemm for CNN ops, scratch-tensor attention and
//!   gather/stream rules for the sequence ops): an
//!   `Iterator<Item = Access>`, never a materialized trace.
//! * [`sim`] — the simulation loop and the Fig 7 capacity sweep, run as a
//!   single-pass multi-capacity (Mattson stack-distance) simulation.

pub mod cache;
pub mod config;
pub mod sim;
pub mod trace;

pub use cache::{Cache, Outcome};
pub use config::GpuConfig;
pub use sim::{capacity_sweep, fig7_capacities, simulate, CapacitySweepSim, SimResult, SweepPoint};
pub use trace::{net_trace, Access, TraceGen};
