//! Trace-driven GPU memory-hierarchy simulator (paper §3.4 → Fig 7) —
//! the stand-in for the extended GPGPU-Sim + DarkNet stack.
//!
//! The paper's iso-area question is: *if the L2 were bigger (same area,
//! denser MRAM cells), how much DRAM traffic disappears?* GPGPU-Sim
//! answers it by simulating AlexNet at L2 capacities from 3MB (the real
//! GTX 1080 Ti) doubled up to 24MB. Here:
//!
//! * [`config`] — the Table 4 GPU configuration plus [`CacheConfig`], the
//!   data-driven hierarchy configuration (replacement policy × write
//!   policy × L1 on/off) threaded through engine queries, explore axes,
//!   `.tech` descriptor `[cache]` sections and the CLI.
//! * [`cache`] — the policy-generic set-associative cache:
//!   [`ReplacementPolicy`] implementations (true LRU — bit-identical to
//!   the seed, pinned in `tests/golden.rs` — tree-PLRU, SRRIP) and
//!   [`WritePolicy`] handling (write-back, write-through, and the
//!   NVM-aware write-bypass that streams write misses past the LLC).
//! * [`trace`] — streaming address-trace compilation from the workload
//!   IR (im2col + tiled sgemm for CNN ops, scratch-tensor attention and
//!   gather/stream rules for the sequence ops): an
//!   `Iterator<Item = Access>`, never a materialized trace.
//! * [`ctrace`] — delta/varint-compressed trace blocks
//!   ([`CompressedTrace`]): what the sharded replay engine holds in
//!   memory instead of wide `Access` records, decoded streaming per
//!   shard (≈5–8× smaller; lossless, so counters are untouched).
//! * [`sim`] — the simulation loop: the [`Hierarchy`] (optional
//!   per-SM-aggregate L1 in front of the L2), warmup-then-measure
//!   support, the **set-sharded parallel** replay engine
//!   ([`simulate_sharded`] — exact counter equality with sequential
//!   replay), and the Fig 7 capacity sweep (single-pass Mattson
//!   stack-distance for the LRU/write-back default,
//!   [`capacity_sweep_config`] per-capacity sharded replay otherwise),
//!   plus [`simulate_with_faults`] — the same replay with a
//!   [`crate::reliability`] injector armed on the L2, shard-deterministic
//!   by per-set RNG streams and bit-identical to the fault-free paths
//!   when disarmed — and [`simulate_backend`] / [`simulate_full`], which
//!   put a [`crate::membackend`] memory device behind the L2 (row-buffer
//!   and bank-traffic counters in `SimResult::dram`, merged exactly
//!   across shards), and the **multi-configuration single-pass replay**
//!   ([`simulate_group`]): one shared partition ([`group_modulus`] gcd
//!   set-residue geometry) drives N independent [`ReplayConfig`]
//!   hierarchies per decoded block — decode once, probe many — with every
//!   member bit-identical to its standalone [`simulate_full`] run.

pub mod cache;
pub mod config;
pub mod ctrace;
pub mod sim;
pub mod trace;

pub use cache::{
    Cache, CacheCounters, Outcome, PolicyCache, Replacement, ReplacementPolicy, Srrip, TreePlru,
    TrueLru, WritePolicy,
};
pub use config::{parse_faults, parse_l1, CacheConfig, GpuConfig};
pub use ctrace::{CompressedTrace, Decoder, BLOCK_ACCESSES};
pub use sim::{
    capacity_sweep, capacity_sweep_config, fig7_capacities, group_modulus, simulate,
    simulate_backend, simulate_config, simulate_full, simulate_group, simulate_sharded,
    simulate_with_faults, CapacitySweepSim, Hierarchy, L1Result, ReplayConfig, ShardedTrace,
    SimResult, SweepPoint, GROUP_CHUNK,
};
pub use trace::{net_trace, Access, TraceGen};
