//! The trace-driven simulation loop, the Fig 7 capacity sweep, and the
//! set-sharded parallel replay engine.
//!
//! Three simulation strategies share one counter vocabulary
//! ([`SimResult`]):
//!
//! * [`simulate`] / [`simulate_config`] — sequential replay of one trace
//!   through one [`Hierarchy`] (optional aggregate L1 in front of the
//!   policy-configured L2), with an optional warmup prefix whose counters
//!   are discarded (`--warmup-frac`).
//! * [`simulate_sharded`] — the same replay partitioned **by set index**
//!   across `par_map` workers. Cache state is set-local (tags, dirty
//!   bits, and every replacement policy's metadata touch only the
//!   accessed set), so a partition of the trace by set residue class
//!   replays each set's access subsequence in order and the merged
//!   counters are *exactly* the sequential counters — verified per access
//!   class in `tests/hierarchy.rs`.
//! * [`CapacitySweepSim`] — the **single-pass multi-capacity** simulation
//!   for the LRU/write-back default: one traversal of the (streamed)
//!   trace computes exact hits/misses/writebacks for every capacity at
//!   once via per-set LRU recency stacks (Mattson's stack algorithm
//!   generalized to set-associative caches). All swept capacities share
//!   the L2 line size and associativity, so each capacity only changes
//!   the set count; capacities whose set counts are integer multiples of
//!   a common base share one stack walk — a line's LRU stack distance
//!   within a member's set is the number of more-recently-touched
//!   distinct lines of the same residue class, and the access hits iff
//!   that distance is below the associativity. Capacities with
//!   incommensurate set counts (7 MB and 10 MB in the Fig 7 sweep) fall
//!   back to a plain set-associative model, still fed by the same single
//!   trace traversal.
//!
//! Mattson stacks assume an inclusion-ordered policy, so the single-pass
//! sweep applies to the default configuration only; non-default policies
//! (PLRU/SRRIP, write-through/bypass, L1 on) sweep capacities by
//! [`capacity_sweep_config`]'s per-capacity sharded replay instead.
//!
//! Versus the old replay-per-capacity loop the single-pass sweep turns
//! O(trace × capacities) work + O(trace) memory into one O(trace) pass +
//! O(working set) memory, and lets trace generation fuse with simulation
//! (no materialized `Vec<Access>`).

use std::collections::{HashMap, HashSet, VecDeque};

use super::cache::{Cache, Outcome, PolicyCache, Replacement, Srrip, TreePlru, WritePolicy};
use super::config::{CacheConfig, GpuConfig};
use super::ctrace::{CompressedTrace, BLOCK_ACCESSES};
use super::trace::Access;
use crate::membackend::{DramStats, MemBackend, MemBackendConfig, MemoryBackend};
use crate::reliability::{FaultConfig, FaultState};
use crate::util::pool::{par_map, par_map_indexed};
use crate::util::units::MB;

/// Result of running one trace through one cache configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SimResult {
    /// L2 capacity simulated (bytes).
    pub l2_bytes: u64,
    /// Accesses the L2 observed (post-L1 when the L1 level is enabled).
    pub l2_accesses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    /// Dirty evictions (write-back DRAM traffic).
    pub writebacks: u64,
    pub l2_write_hits: u64,
    pub l2_write_misses: u64,
    /// Writes that updated the L2 array (hit updates + write-allocate
    /// installs) — the quantity NVM write energy is charged on.
    pub l2_array_writes: u64,
    /// Line fills from DRAM (== `l2_misses` under write-allocate; smaller
    /// under no-allocate write policies).
    pub dram_fills: u64,
    /// DRAM-bound writes: writebacks plus through/bypassed write traffic.
    pub dram_writes: u64,
    /// Accesses replayed (and discarded) as cache warmup before counting.
    pub warmup_accesses: u64,
    /// Faults the ECC layer corrected in flight (fault injection only;
    /// identically zero on fault-free runs, like the three below).
    pub faults_corrected: u64,
    /// Detected-but-uncorrectable faults (refetch/stall events).
    pub faults_detected: u64,
    /// Faults that escaped ECC undetected — the UBER numerator.
    pub faults_silent: u64,
    /// L2 ways retired after crossing the endurance budget.
    pub retired_ways: u64,
    /// Heaviest per-line physical write count (wear pacemaker; array
    /// lifetime is extrapolated from it).
    pub max_line_writes: u64,
    /// Main-memory backend observations (row hits/misses/conflicts,
    /// per-channel and per-bank traffic). Identically zero under the
    /// default [`MemBackendConfig::FixedLatency`] backend, so default
    /// results stay bit-identical to the pre-backend seed.
    pub dram: DramStats,
    /// Present when the L1 level was simulated.
    pub l1: Option<L1Result>,
}

/// Counters of the aggregate L1 level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct L1Result {
    /// Accesses offered to the hierarchy (pre-filtering).
    pub accesses: u64,
    /// L1 hits (read hits are filtered from the L2 stream; writes pass
    /// through regardless).
    pub hits: u64,
}

impl SimResult {
    fn zero(l2_bytes: u64) -> SimResult {
        SimResult {
            l2_bytes,
            l2_accesses: 0,
            l2_hits: 0,
            l2_misses: 0,
            writebacks: 0,
            l2_write_hits: 0,
            l2_write_misses: 0,
            l2_array_writes: 0,
            dram_fills: 0,
            dram_writes: 0,
            warmup_accesses: 0,
            faults_corrected: 0,
            faults_detected: 0,
            faults_silent: 0,
            retired_ways: 0,
            max_line_writes: 0,
            dram: DramStats::default(),
            l1: None,
        }
    }

    /// DRAM transactions: every line fill plus every DRAM-bound write
    /// (dirty evictions, write-through, and bypassed write misses). Equals
    /// the classic `misses + writebacks` under the default configuration.
    pub fn dram_accesses(&self) -> u64 {
        self.dram_fills + self.dram_writes
    }

    pub fn l2_hit_rate(&self) -> f64 {
        self.l2_hits as f64 / self.l2_accesses.max(1) as f64
    }

    fn merge_from(&mut self, other: &SimResult) {
        self.l2_accesses += other.l2_accesses;
        self.l2_hits += other.l2_hits;
        self.l2_misses += other.l2_misses;
        self.writebacks += other.writebacks;
        self.l2_write_hits += other.l2_write_hits;
        self.l2_write_misses += other.l2_write_misses;
        self.l2_array_writes += other.l2_array_writes;
        self.dram_fills += other.dram_fills;
        self.dram_writes += other.dram_writes;
        self.warmup_accesses += other.warmup_accesses;
        self.faults_corrected += other.faults_corrected;
        self.faults_detected += other.faults_detected;
        self.faults_silent += other.faults_silent;
        self.retired_ways += other.retired_ways;
        // Shards own disjoint sets, so the global wear maximum is the
        // maximum over shards.
        self.max_line_writes = self.max_line_writes.max(other.max_line_writes);
        // Plain sums: commutative, so shard merge order is irrelevant.
        self.dram.merge_from(&other.dram);
        self.l1 = match (self.l1, other.l1) {
            (None, b) => b,
            (a, None) => a,
            (Some(a), Some(b)) => {
                Some(L1Result { accesses: a.accesses + b.accesses, hits: a.hits + b.hits })
            }
        };
    }
}

/// The L2 level with its replacement policy selected at runtime — one
/// `match` per run setup, monomorphized loops per access.
enum L2 {
    Lru(PolicyCache<super::cache::TrueLru>),
    Plru(PolicyCache<TreePlru>),
    Srrip(PolicyCache<Srrip>),
}

impl L2 {
    fn new(config: &GpuConfig, cache: CacheConfig) -> L2 {
        let (cap, line, assoc) = (config.l2_bytes, config.l2_line, config.l2_assoc);
        match cache.replacement {
            Replacement::Lru => {
                L2::Lru(PolicyCache::with_write_policy(cap, line, assoc, cache.write))
            }
            Replacement::TreePlru => {
                L2::Plru(PolicyCache::with_write_policy(cap, line, assoc, cache.write))
            }
            Replacement::Srrip => {
                L2::Srrip(PolicyCache::with_write_policy(cap, line, assoc, cache.write))
            }
        }
    }

    #[inline]
    fn access(&mut self, addr: u64, write: bool) -> Outcome {
        match self {
            L2::Lru(c) => c.access(addr, write),
            L2::Plru(c) => c.access(addr, write),
            L2::Srrip(c) => c.access(addr, write),
        }
    }

    fn counters(&self) -> super::cache::CacheCounters {
        match self {
            L2::Lru(c) => c.counters(),
            L2::Plru(c) => c.counters(),
            L2::Srrip(c) => c.counters(),
        }
    }

    fn attach_faults(&mut self, faults: FaultState) {
        match self {
            L2::Lru(c) => c.attach_faults(faults),
            L2::Plru(c) => c.attach_faults(faults),
            L2::Srrip(c) => c.attach_faults(faults),
        }
    }

    fn faults(&self) -> Option<&FaultState> {
        match self {
            L2::Lru(c) => c.faults(),
            L2::Plru(c) => c.faults(),
            L2::Srrip(c) => c.faults(),
        }
    }

    fn reset_counters(&mut self) {
        match self {
            L2::Lru(c) => c.reset_counters(),
            L2::Plru(c) => c.reset_counters(),
            L2::Srrip(c) => c.reset_counters(),
        }
    }
}

/// The simulated memory hierarchy: an optional aggregate L1 (Table 4
/// `l1_*` fields, write-through / no-write-allocate, true-LRU) in front
/// of the policy-configured L2. Read hits in L1 are filtered from the
/// L2-visible stream; writes pass through (GPU L1s are write-through), so
/// enabling the L1 changes the L2's read mix but never its write mix.
pub struct Hierarchy {
    l1: Option<Cache>,
    l2: L2,
    l2_bytes: u64,
    l2_line: u64,
    /// The memory device behind the L2. The fixed-latency baseline costs
    /// one discriminant check per L2 access; the DRAM model additionally
    /// snapshots the L2 counters around the access to classify the
    /// emitted line traffic.
    backend: MemBackend,
    /// Accesses offered to the hierarchy since the last counter reset.
    offered: u64,
    warmup: u64,
}

impl Hierarchy {
    pub fn new(config: &GpuConfig, cache: CacheConfig) -> Hierarchy {
        Hierarchy::with_faults(config, cache, None)
    }

    /// [`Hierarchy::new`] with an optional fault injector armed on the L2
    /// (the NVM array; the SRAM L1 is never injected). The injector's
    /// per-set RNG streams are keyed by global set index, so building one
    /// per shard and replaying disjoint set subsets merges exactly.
    pub fn with_faults(
        config: &GpuConfig,
        cache: CacheConfig,
        faults: Option<FaultConfig>,
    ) -> Hierarchy {
        Hierarchy::with_backend(config, cache, faults, &MemBackendConfig::FixedLatency)
    }

    /// [`Hierarchy::with_faults`] with an explicit memory backend behind
    /// the L2. The DRAM model's open-row state is keyed by the L2 set
    /// index, the same modulus the set-sharded partition respects, so
    /// per-shard backends merge exactly (see [`crate::membackend`]).
    pub fn with_backend(
        config: &GpuConfig,
        cache: CacheConfig,
        faults: Option<FaultConfig>,
        backend: &MemBackendConfig,
    ) -> Hierarchy {
        let l1 = cache.l1.then(|| {
            PolicyCache::with_write_policy(
                config.l1_aggregate_bytes(),
                config.l1_line,
                config.l1_assoc,
                WritePolicy::WriteThrough,
            )
        });
        let mut l2 = L2::new(config, cache);
        if let Some(fc) = faults {
            let sets = (config.l2_bytes / config.l2_line / config.l2_assoc) as usize;
            l2.attach_faults(FaultState::new(
                &fc,
                sets,
                config.l2_assoc as usize,
                config.l2_line * 8,
            ));
        }
        Hierarchy {
            l1,
            l2,
            l2_bytes: config.l2_bytes,
            l2_line: config.l2_line,
            backend: MemBackend::from_config(backend, config.l2_line, config.l2_sets()),
            offered: 0,
            warmup: 0,
        }
    }

    /// Feed one access through the hierarchy.
    #[inline]
    pub fn access(&mut self, addr: u64, write: bool) {
        self.offered += 1;
        let to_l2 = match &mut self.l1 {
            None => true,
            Some(l1) => {
                let out = l1.access(addr, write);
                // Writes always reach L2 (write-through); reads only on miss.
                write || out != Outcome::Hit
            }
        };
        if to_l2 {
            if self.backend.is_fixed() {
                self.l2.access(addr, write);
            } else {
                // Classify the line traffic this access emits by the L2
                // counter deltas: Δfills is the DRAM read, Δ(writebacks +
                // direct_writes) the DRAM-bound writes (a dirty eviction,
                // a through/bypassed store, or both when a fault
                // retirement flushes alongside). The victim's address is
                // not surfaced by the cache, so writebacks are attributed
                // to the triggering line — same set, hence same shard
                // context, which keeps sharded replay exact. Reads are
                // modeled before writes within one access.
                let before = self.l2.counters();
                self.l2.access(addr, write);
                let after = self.l2.counters();
                let line_addr = addr / self.l2_line;
                for _ in 0..after.fills - before.fills {
                    self.backend.read(line_addr);
                }
                let writes = (after.writebacks + after.direct_writes)
                    - (before.writebacks + before.direct_writes);
                for _ in 0..writes {
                    self.backend.write(line_addr);
                }
            }
        }
    }

    /// End the warmup phase: discard counters (cache contents retained)
    /// and record how many accesses warmed the hierarchy.
    pub fn start_measurement(&mut self) {
        self.warmup += self.offered;
        self.offered = 0;
        self.l2.reset_counters();
        self.backend.reset_stats();
        if let Some(l1) = &mut self.l1 {
            l1.reset_counters();
        }
    }

    /// Final counters as a [`SimResult`].
    pub fn finish(self) -> SimResult {
        let c = self.l2.counters();
        let dram = self.backend.stats();
        let f = self.l2.faults();
        let (corrected, detected, silent, retired, max_wear) = match f {
            None => (0, 0, 0, 0, 0),
            Some(f) => (f.corrected, f.detected, f.silent, f.retired_ways, f.max_wear()),
        };
        let out = SimResult {
            l2_bytes: self.l2_bytes,
            l2_accesses: c.hits + c.misses,
            l2_hits: c.hits,
            l2_misses: c.misses,
            writebacks: c.writebacks,
            l2_write_hits: c.write_hits,
            l2_write_misses: c.write_misses,
            l2_array_writes: c.array_writes,
            dram_fills: c.fills,
            dram_writes: c.writebacks + c.direct_writes,
            warmup_accesses: self.warmup,
            faults_corrected: corrected,
            faults_detected: detected,
            faults_silent: silent,
            retired_ways: retired,
            max_line_writes: max_wear,
            dram,
            l1: self.l1.map(|l1| L1Result { accesses: self.offered, hits: l1.hits }),
        };
        record_finish_metrics(&out);
        out
    }
}

/// Mirror one finished hierarchy's counters into the telemetry metrics
/// registry. Every replay — each parallel shard, the sequential path, a
/// fault-campaign trial — finishes exactly once, so counter sums across a
/// process equal the merged totals. Zero deltas still register their
/// keys, so a fixed-latency run reports explicit zero DRAM row-class
/// counters. No-op while the sink is disabled.
fn record_finish_metrics(r: &SimResult) {
    if !crate::telemetry::enabled() {
        return;
    }
    use crate::telemetry::counter_add;
    counter_add("gpusim.replays", 1);
    counter_add("gpusim.l2.accesses", r.l2_accesses);
    counter_add("gpusim.l2.hits", r.l2_hits);
    counter_add("gpusim.l2.misses", r.l2_misses);
    counter_add("gpusim.dram.fills", r.dram_fills);
    counter_add("gpusim.dram.writes", r.dram_writes);
    counter_add("membackend.row_hits", r.dram.row_hits);
    counter_add("membackend.row_misses", r.dram.row_misses);
    counter_add("membackend.row_conflicts", r.dram.row_conflicts);
    counter_add("membackend.queue_excess", r.dram.queue_excess());
    counter_add("reliability.corrected", r.faults_corrected);
    counter_add("reliability.detected", r.faults_detected);
    counter_add("reliability.silent", r.faults_silent);
    counter_add("reliability.retired_ways", r.retired_ways);
}

/// Run `trace` through the shared L2 of `config` — the seed entrypoint
/// (default policies, no L1, no warmup).
pub fn simulate(trace: impl IntoIterator<Item = Access>, config: &GpuConfig) -> SimResult {
    simulate_config(trace, config, CacheConfig::default(), 0)
}

/// Sequential replay under an explicit [`CacheConfig`]. The first
/// `warmup_accesses` accesses warm the hierarchy without counting
/// (`SimResult::warmup_accesses` records how many actually ran).
pub fn simulate_config(
    trace: impl IntoIterator<Item = Access>,
    config: &GpuConfig,
    cache: CacheConfig,
    warmup_accesses: u64,
) -> SimResult {
    simulate_seq(
        trace,
        config,
        cache,
        warmup_accesses,
        None,
        &MemBackendConfig::FixedLatency,
    )
}

/// Sequential replay with an optional fault injector and memory backend.
fn simulate_seq(
    trace: impl IntoIterator<Item = Access>,
    config: &GpuConfig,
    cache: CacheConfig,
    warmup_accesses: u64,
    faults: Option<FaultConfig>,
    backend: &MemBackendConfig,
) -> SimResult {
    // One shard: keep the span vocabulary of the sharded path so traces
    // show a `gpusim.shard` replay regardless of core count.
    let _span = crate::span!("gpusim.shard", shard = 0);
    let mut h = Hierarchy::with_backend(config, cache, faults, backend);
    let mut it = trace.into_iter();
    if warmup_accesses > 0 {
        for a in it.by_ref().take(warmup_accesses as usize) {
            h.access(a.addr, a.write);
        }
        h.start_measurement();
    }
    for a in it {
        h.access(a.addr, a.write);
    }
    h.finish()
}

fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a.max(1)
}

/// Set-sharded parallel replay: partition the trace by set residue class
/// into at most `max_shards` shards, replay each shard on its own
/// [`Hierarchy`] through the thread pool, and merge counters. Counter
/// totals are **exactly** the sequential [`simulate_config`] totals:
/// every outcome depends only on the accessed set's prior state, and the
/// shard key (`line_address mod g`, with `g` dividing every simulated
/// level's set count) keeps each set's accesses together and in order.
///
/// The partition pass materializes the trace, but in delta/varint
/// compressed form ([`CompressedTrace`], ≈2–3 bytes per access instead of
/// a 16-byte `Access`); each shard decodes its stream on the fly during
/// replay. The streaming single-pass sweep remains the memory-frugal
/// default-configuration path.
pub fn simulate_sharded(
    trace: impl IntoIterator<Item = Access>,
    config: &GpuConfig,
    cache: CacheConfig,
    warmup_accesses: u64,
    max_shards: usize,
) -> SimResult {
    simulate_with_faults(trace, config, cache, warmup_accesses, max_shards, None)
}

/// [`simulate_sharded`] with an explicit memory backend behind the L2.
/// [`MemBackendConfig::FixedLatency`] reproduces [`simulate_sharded`]
/// bit-identically (zero [`DramStats`]); the DRAM model fills
/// `SimResult::dram` with row-buffer and bank-traffic counters whose
/// sharded merge equals the sequential run exactly — the open-row state
/// is keyed by L2 set index, which every shard partition respects
/// (differential tests in `tests/membackend.rs`).
pub fn simulate_backend(
    trace: impl IntoIterator<Item = Access>,
    config: &GpuConfig,
    cache: CacheConfig,
    warmup_accesses: u64,
    max_shards: usize,
    backend: &MemBackendConfig,
) -> SimResult {
    simulate_full(trace, config, cache, warmup_accesses, max_shards, None, backend)
}

/// [`simulate_sharded`] with an optional fault injector armed on the L2.
/// Fault counts are **shard-deterministic**: per-set RNG streams are
/// keyed by set index and advance only on that set's accesses, and the
/// set-sharded partition preserves per-set order — so any worker count
/// (including 1) yields bit-identical fault counters for a given seed
/// (pinned in `tests/reliability.rs`). With `faults: None` this is
/// exactly [`simulate_sharded`].
pub fn simulate_with_faults(
    trace: impl IntoIterator<Item = Access>,
    config: &GpuConfig,
    cache: CacheConfig,
    warmup_accesses: u64,
    max_shards: usize,
    faults: Option<FaultConfig>,
) -> SimResult {
    simulate_full(
        trace,
        config,
        cache,
        warmup_accesses,
        max_shards,
        faults,
        &MemBackendConfig::FixedLatency,
    )
}

/// The fully general sharded entrypoint: fault injector and memory
/// backend together. Every other `simulate_*` function delegates here.
pub fn simulate_full(
    trace: impl IntoIterator<Item = Access>,
    config: &GpuConfig,
    cache: CacheConfig,
    warmup_accesses: u64,
    max_shards: usize,
    faults: Option<FaultConfig>,
    backend: &MemBackendConfig,
) -> SimResult {
    let group = shard_group(config, cache);
    let shards = group.min(max_shards.max(1) as u64).max(1) as usize;
    if shards <= 1 {
        return simulate_seq(trace, config, cache, warmup_accesses, faults, backend);
    }
    let parts =
        ShardedTrace::partition_by(trace, config.l2_line, group, shards, warmup_accesses);
    parts.replay(config, cache, faults, backend)
}

/// Multi-configuration single-pass replay: partition `trace` once for the
/// whole group ([`ShardedTrace::partition_group`]) and replay every
/// member in one decode pass ([`ShardedTrace::replay_group`]). Results
/// align with `configs`; each is bit-identical to the corresponding
/// per-candidate [`simulate_full`] call. This is the batched engine the
/// explore fan-out, figWP/figMem/figRel, and `Engine::evaluate_many`
/// grouping ride.
pub fn simulate_group(
    trace: impl IntoIterator<Item = Access>,
    configs: &[ReplayConfig],
    warmup_accesses: u64,
    max_shards: usize,
) -> Vec<SimResult> {
    let parts = ShardedTrace::partition_group(trace, configs, warmup_accesses, max_shards);
    parts.replay_group(configs)
}

/// Largest shard-key modulus valid for one hierarchy: the shard key must
/// be constant across every set an access touches. Without an L1 that is
/// the L2 set count (any divisor works); with an L1 it must also respect
/// the L1 set mapping, which shares the key's `addr / line` granularity
/// only when the line sizes agree (1 = sharding disabled).
fn shard_group(config: &GpuConfig, cache: CacheConfig) -> u64 {
    if cache.l1 {
        if config.l1_line == config.l2_line {
            gcd(config.l2_sets(), config.l1_aggregate_sets())
        } else {
            1
        }
    } else {
        config.l2_sets()
    }
}

/// One candidate of a multi-configuration single-pass replay (MCSR)
/// group: the full hierarchy recipe [`simulate_full`] takes, as data. A
/// slice of these is a *config group* — [`simulate_group`] partitions the
/// shared trace once and drives every decoded block through each member's
/// [`Hierarchy`] in one pass (decode once, probe many), with per-member
/// counters bit-identical to the standalone `simulate_full` call.
#[derive(Debug, Clone)]
pub struct ReplayConfig {
    /// GPU geometry (L2 capacity/line/associativity, L1 shape).
    pub config: GpuConfig,
    /// Replacement policy, write policy, and the L1 toggle.
    pub cache: CacheConfig,
    /// Optional fault injector armed on the L2.
    pub faults: Option<FaultConfig>,
    /// Memory backend behind the L2.
    pub backend: MemBackendConfig,
}

impl ReplayConfig {
    /// The fault-free fixed-latency case (the explore / figWP shape).
    pub fn new(config: GpuConfig, cache: CacheConfig) -> ReplayConfig {
        ReplayConfig { config, cache, faults: None, backend: MemBackendConfig::FixedLatency }
    }

    fn hierarchy(&self) -> Hierarchy {
        Hierarchy::with_backend(&self.config, self.cache, self.faults, &self.backend)
    }
}

/// Configs per MCSR pool task: each (shard × chunk) task decodes its
/// shard's blocks once and probes up to this many hierarchies from the
/// same decoded buffer. Small enough that a skewed hot shard still splits
/// across workers for stealing to balance; large enough to amortize the
/// decode by close to an order of magnitude (BENCH_batch records the
/// realized factor).
pub const GROUP_CHUNK: usize = 8;

/// Largest shard-key modulus valid for **every** member of a config
/// group: the gcd of the members' per-config moduli. Any common divisor
/// of every simulated level's set count preserves per-set access order
/// for all members at once, so one partition serves the whole group —
/// the same argument [`capacity_sweep_config`] uses for its shared
/// per-capacity partition. An L1-enabled member with mismatched line
/// sizes contributes 1, collapsing the group to a single shard (still
/// exact, just serial per chunk).
pub fn group_modulus(configs: &[ReplayConfig]) -> u64 {
    configs.iter().map(|rc| shard_group(&rc.config, rc.cache)).fold(0, gcd).max(1)
}

/// A trace partitioned by set residue class into per-shard compressed
/// streams — the sharded replay engine's in-memory representation.
/// Partition once, replay many times: the capacity sweep replays one
/// partition per capacity, and the scheduler benchmarks time [`replay`]
/// with the (serial) partition cost excluded.
///
/// Each shard holds a [`CompressedTrace`] (delta/varint blocks, ≈2–3
/// bytes per access) that replay decodes on the fly; decoding is lossless
/// so counters are bit-identical to replaying the raw `Access` stream.
///
/// [`replay`]: ShardedTrace::replay
#[derive(Debug, Clone)]
pub struct ShardedTrace {
    /// Per-shard compressed stream and its share of the warmup prefix.
    parts: Vec<(CompressedTrace, u64)>,
    /// Whether a warmup prefix was requested (replay resets counters
    /// after it even for shards whose own share is empty).
    warmup: bool,
}

impl ShardedTrace {
    /// Partition `trace` for hierarchies of this `config`/`cache` shape:
    /// shard key `(addr / line) mod group` folded onto at most
    /// `max_shards` buckets, the first `warmup_accesses` accesses flagged
    /// as the warmup prefix.
    pub fn partition(
        trace: impl IntoIterator<Item = Access>,
        config: &GpuConfig,
        cache: CacheConfig,
        warmup_accesses: u64,
        max_shards: usize,
    ) -> ShardedTrace {
        let group = shard_group(config, cache);
        let shards = group.min(max_shards.max(1) as u64).max(1) as usize;
        ShardedTrace::partition_by(trace, config.l2_line, group, shards, warmup_accesses)
    }

    /// Partition with an explicit shard-key modulus (`group` must divide
    /// every simulated level's set count — [`ShardedTrace::partition`]
    /// derives it from the configuration).
    fn partition_by(
        trace: impl IntoIterator<Item = Access>,
        line: u64,
        group: u64,
        shards: usize,
        warmup_accesses: u64,
    ) -> ShardedTrace {
        let mut parts: Vec<(CompressedTrace, u64)> =
            (0..shards).map(|_| (CompressedTrace::new(), 0)).collect();
        for (i, a) in trace.into_iter().enumerate() {
            let k = (((a.addr / line) % group) % shards as u64) as usize;
            if (i as u64) < warmup_accesses {
                parts[k].1 += 1;
            }
            parts[k].0.push(a);
        }
        ShardedTrace { parts, warmup: warmup_accesses > 0 }
    }

    /// Number of shard buckets.
    pub fn num_shards(&self) -> usize {
        self.parts.len()
    }

    /// Total accesses across shards.
    pub fn len(&self) -> usize {
        self.parts.iter().map(|(t, _)| t.len()).sum()
    }

    /// Whether every shard is empty.
    pub fn is_empty(&self) -> bool {
        self.parts.iter().all(|(t, _)| t.is_empty())
    }

    /// Accesses in shard `s` (the skewed-load bench asserts its hot-shard
    /// fraction through this).
    pub fn shard_len(&self, s: usize) -> usize {
        self.parts[s].0.len()
    }

    /// Total encoded bytes across shards (BENCH_sim divides by
    /// [`ShardedTrace::len`] for its bytes/access record).
    pub fn byte_len(&self) -> usize {
        self.parts.iter().map(|(t, _)| t.byte_len()).sum()
    }

    /// Replay every shard on its own [`Hierarchy`] through the thread
    /// pool and merge counters — bit-identical to sequential replay of
    /// the unpartitioned trace, for any worker count.
    pub fn replay(
        &self,
        config: &GpuConfig,
        cache: CacheConfig,
        faults: Option<FaultConfig>,
        backend: &MemBackendConfig,
    ) -> SimResult {
        let results = par_map_indexed(&self.parts, |shard, (accesses, warm)| {
            let _span = crate::span!("gpusim.shard", shard = shard, accesses = accesses.len());
            let mut h = Hierarchy::with_backend(config, cache, faults, backend);
            let mut it = accesses.iter();
            for a in it.by_ref().take(*warm as usize) {
                h.access(a.addr, a.write);
            }
            if self.warmup {
                h.start_measurement();
            }
            for a in it {
                h.access(a.addr, a.write);
            }
            h.finish()
        });
        let t_merge = std::time::Instant::now();
        let mut out = SimResult::zero(config.l2_bytes);
        for r in &results {
            out.merge_from(r);
        }
        if crate::telemetry::enabled() {
            crate::telemetry::observe("gpusim.merge_s", t_merge.elapsed().as_secs_f64());
            for (accesses, _) in &self.parts {
                crate::telemetry::observe("gpusim.shard.accesses", accesses.len() as f64);
            }
        }
        out
    }

    /// Partition `trace` once for a whole config group: the shard-key
    /// modulus is [`group_modulus`] (valid for every member) folded onto
    /// at most `max_shards` buckets. Every member must share one L2 line
    /// size — the shard key works at `addr / line` granularity.
    pub fn partition_group(
        trace: impl IntoIterator<Item = Access>,
        configs: &[ReplayConfig],
        warmup_accesses: u64,
        max_shards: usize,
    ) -> ShardedTrace {
        assert!(!configs.is_empty(), "a config group needs at least one member");
        let line = configs[0].config.l2_line;
        assert!(
            configs.iter().all(|rc| rc.config.l2_line == line),
            "a config group shares one L2 line size (the shard-key granularity)"
        );
        let group = group_modulus(configs);
        let shards = group.min(max_shards.max(1) as u64).max(1) as usize;
        ShardedTrace::partition_by(trace, line, group, shards, warmup_accesses)
    }

    /// Multi-configuration single-pass replay: decode each shard's blocks
    /// once per config chunk and probe every member [`Hierarchy`] from the
    /// same decoded buffer. Results align with `configs`, and each is
    /// bit-identical to a standalone [`simulate_full`] run of that member
    /// (any shard modulus dividing every level's set count reproduces the
    /// sequential counters; the differential matrix lives in
    /// `tests/mcsr.rs`). The partition must have been built for a group
    /// modulus every member admits — [`ShardedTrace::partition_group`]
    /// over a superset of `configs` guarantees that.
    ///
    /// Work dispatches through the pool as one task per (shard × chunk of
    /// [`GROUP_CHUNK`] configs), so the work-stealing scheduler balances
    /// skewed set-residue classes exactly as in the single-config replay.
    pub fn replay_group(&self, configs: &[ReplayConfig]) -> Vec<SimResult> {
        assert!(!configs.is_empty(), "a config group needs at least one member");
        let chunks: Vec<&[ReplayConfig]> = configs.chunks(GROUP_CHUNK).collect();
        // Shard-major task order: a shard's chunks replay the same
        // compressed bytes, so adjacent queue slots share cache footprint.
        let tasks: Vec<(usize, usize)> = (0..self.parts.len())
            .flat_map(|s| (0..chunks.len()).map(move |c| (s, c)))
            .collect();
        let results = par_map(&tasks, |&(s, c)| self.replay_chunk(s, chunks[c]));
        let t_merge = std::time::Instant::now();
        let mut out: Vec<SimResult> =
            configs.iter().map(|rc| SimResult::zero(rc.config.l2_bytes)).collect();
        let (mut decode_s, mut probe_s) = (0.0, 0.0);
        for (&(_, c), (partials, d, p)) in tasks.iter().zip(results) {
            for (i, r) in partials.iter().enumerate() {
                out[c * GROUP_CHUNK + i].merge_from(r);
            }
            decode_s += d;
            probe_s += p;
        }
        if crate::telemetry::enabled() {
            crate::telemetry::counter_add("sim.group.replays", 1);
            crate::telemetry::counter_add("sim.group.configs", configs.len() as u64);
            crate::telemetry::observe("sim.group.size", configs.len() as f64);
            crate::telemetry::observe("sim.group.decode_s", decode_s);
            crate::telemetry::observe("sim.group.probe_s", probe_s);
            crate::telemetry::observe("gpusim.merge_s", t_merge.elapsed().as_secs_f64());
        }
        out
    }

    /// Replay one shard through one chunk of group members, block by
    /// block: each block decodes once into a reusable buffer, then every
    /// member hierarchy replays it (warmup split included). Returns the
    /// per-member results plus this task's decode/probe wall-time split.
    fn replay_chunk(&self, shard: usize, chunk: &[ReplayConfig]) -> (Vec<SimResult>, f64, f64) {
        let (trace, warm) = &self.parts[shard];
        let _span = crate::span!(
            "gpusim.group.task",
            shard = shard,
            configs = chunk.len(),
            accesses = trace.len(),
        );
        let mut hierarchies: Vec<Hierarchy> = chunk.iter().map(ReplayConfig::hierarchy).collect();
        let mut buf: Vec<Access> = Vec::with_capacity(BLOCK_ACCESSES.min(trace.len()));
        let (mut decode_s, mut probe_s) = (0.0, 0.0);
        // Accesses replayed so far; while below the shard's warmup share
        // the counters are still pre-measurement.
        let mut pos: u64 = 0;
        let mut measuring = !self.warmup;
        for b in 0..trace.num_blocks() {
            let t_decode = std::time::Instant::now();
            trace.decode_block(b, &mut buf);
            let t_probe = std::time::Instant::now();
            decode_s += (t_probe - t_decode).as_secs_f64();
            if measuring {
                for h in &mut hierarchies {
                    for a in &buf {
                        h.access(a.addr, a.write);
                    }
                }
            } else {
                // The warmup prefix ends inside (or exactly at the end
                // of) this shard: split the block and reset counters at
                // the boundary, matching `replay`'s take(warm) split.
                let split = ((*warm - pos) as usize).min(buf.len());
                pos += split as u64;
                let boundary = pos == *warm;
                for h in &mut hierarchies {
                    for a in &buf[..split] {
                        h.access(a.addr, a.write);
                    }
                    if boundary {
                        h.start_measurement();
                    }
                    for a in &buf[split..] {
                        h.access(a.addr, a.write);
                    }
                }
                measuring = boundary;
            }
            probe_s += t_probe.elapsed().as_secs_f64();
        }
        if !measuring {
            // Degenerate warmup shard (empty, or fully consumed by the
            // prefix with no boundary block): `replay` still calls
            // `start_measurement` after the prefix, so mirror it.
            for h in &mut hierarchies {
                h.start_measurement();
            }
        }
        (hierarchies.into_iter().map(Hierarchy::finish).collect(), decode_s, probe_s)
    }
}

/// One resident-or-remembered line in a per-set recency stack.
#[derive(Debug, Clone, Copy)]
struct Entry {
    /// Full line address (identity).
    line: u64,
    /// `line / base_sets` — the part of the address that distinguishes the
    /// member set within the base set (residue classes of `q mod ratio`).
    q: u64,
    /// Dirty bit per chain member (bit k = member k's current residency).
    dirty: u32,
}

/// One capacity within a stack chain.
#[derive(Debug, Clone)]
struct Member {
    cap: u64,
    /// This member's set count divided by the chain's base set count.
    ratio: u64,
    /// `ratio - 1` when `ratio` is a power of two (XOR/AND class test).
    mask: u64,
    pow2: bool,
    hits: u64,
    misses: u64,
    writebacks: u64,
    write_hits: u64,
    write_misses: u64,
}

/// Capacities whose set counts are integer multiples of a common base,
/// resolved together by one recency-stack walk per access.
#[derive(Debug)]
struct StackChain {
    base_sets: u64,
    assoc: u32,
    members: Vec<Member>,
    /// One MRU-first recency stack per base set.
    stacks: Vec<VecDeque<Entry>>,
    /// Lines currently held in some stack (gates the stale-duplicate scan).
    present: HashSet<u64>,
    /// Stack length that triggers a dead-entry prune (2× the resident
    /// bound `assoc · Σ ratio`, so pruning amortizes to O(1) per access).
    prune_limit: usize,
    /// Scratch: per-member match count for the current walk.
    counts: Vec<u32>,
    /// Scratch: per-member residue of the current line (`q mod ratio`).
    residue: Vec<u64>,
}

impl StackChain {
    fn new(base_sets: u64, line: u64, assoc: u64, caps: &[u64]) -> StackChain {
        assert!(
            caps.len() <= 31,
            "stack chain dirty mask holds at most 31 members"
        );
        let members: Vec<Member> = caps
            .iter()
            .map(|&cap| {
                let sets = (cap / line) / assoc;
                assert!(sets % base_sets == 0 && sets >= base_sets, "not a chain member");
                let ratio = sets / base_sets;
                Member {
                    cap,
                    ratio,
                    mask: ratio - 1,
                    pow2: ratio.is_power_of_two(),
                    hits: 0,
                    misses: 0,
                    writebacks: 0,
                    write_hits: 0,
                    write_misses: 0,
                }
            })
            .collect();
        let resident_bound: usize =
            assoc as usize * members.iter().map(|m| m.ratio as usize).sum::<usize>();
        StackChain {
            base_sets,
            assoc: assoc as u32,
            stacks: vec![VecDeque::new(); base_sets as usize],
            present: HashSet::new(),
            prune_limit: 2 * resident_bound + 8,
            counts: vec![0; members.len()],
            residue: vec![0; members.len()],
            members,
        }
    }

    /// One access to `line` (a line address, not a byte address).
    ///
    /// Walks the line's base-set recency stack front-to-back. For member k
    /// the access hits iff fewer than `assoc` distinct lines of the same
    /// `q mod ratio_k` class sit above the line; the `assoc`-th such line
    /// encountered is exactly the LRU way this access would evict on a
    /// miss, which is where writebacks (dirty evictions) are charged. The
    /// walk stops as soon as the line is found (remaining members hit) or
    /// every member has resolved to a miss.
    fn access(&mut self, line: u64, write: bool) {
        let assoc = self.assoc;
        let s0 = (line % self.base_sets) as usize;
        let q = line / self.base_sets;
        let nm = self.members.len();
        let all_mask: u32 = (1u32 << nm) - 1;
        for (k, m) in self.members.iter().enumerate() {
            self.counts[k] = 0;
            self.residue[k] = if m.pow2 { 0 } else { q % m.ratio };
        }
        let stack = &mut self.stacks[s0];

        let mut missed: u32 = 0;
        let mut found: Option<usize> = None;
        let mut i = 0usize;
        while i < stack.len() {
            if stack[i].line == line {
                found = Some(i);
                break;
            }
            let eq = stack[i].q;
            let mut newly_missed = 0u32;
            for (k, m) in self.members.iter_mut().enumerate() {
                let bit = 1u32 << k;
                if missed & bit != 0 {
                    continue;
                }
                let same_set = if m.pow2 {
                    (eq ^ q) & m.mask == 0
                } else {
                    eq % m.ratio == self.residue[k]
                };
                if same_set {
                    self.counts[k] += 1;
                    if self.counts[k] == assoc {
                        // `assoc` set-mates are more recent: member k
                        // misses, and this entry is the LRU way it evicts.
                        m.misses += 1;
                        if write {
                            m.write_misses += 1;
                        }
                        if stack[i].dirty & bit != 0 {
                            m.writebacks += 1;
                        }
                        newly_missed |= bit;
                    }
                }
            }
            if newly_missed != 0 {
                // Evicted residencies end here; clear so a later re-fetch
                // starts clean.
                stack[i].dirty &= !newly_missed;
                missed |= newly_missed;
                if missed == all_mask {
                    // Every member misses. If a stale copy of `line` sits
                    // deeper (evicted everywhere, not yet pruned), drop it
                    // so entries stay unique.
                    if self.present.contains(&line) {
                        if let Some(off) =
                            stack.iter().skip(i + 1).position(|e| e.line == line)
                        {
                            stack.remove(i + 1 + off);
                        }
                    }
                    break;
                }
            }
            i += 1;
        }

        match found {
            Some(pos) => {
                let mut e = stack.remove(pos).expect("indexed within bounds");
                for (k, m) in self.members.iter_mut().enumerate() {
                    let bit = 1u32 << k;
                    if missed & bit != 0 {
                        // Miss already charged (victim observed above);
                        // this access starts a fresh residency.
                        if write {
                            e.dirty |= bit;
                        } else {
                            e.dirty &= !bit;
                        }
                    } else {
                        m.hits += 1;
                        if write {
                            m.write_hits += 1;
                            e.dirty |= bit;
                        }
                    }
                }
                stack.push_front(e);
            }
            None => {
                for (k, m) in self.members.iter_mut().enumerate() {
                    if missed & (1u32 << k) == 0 {
                        // Fewer than `assoc` set-mates above: the member
                        // set still has a free way — miss, no eviction.
                        m.misses += 1;
                        if write {
                            m.write_misses += 1;
                        }
                    }
                }
                let dirty = if write { all_mask } else { 0 };
                stack.push_front(Entry { line, q, dirty });
                self.present.insert(line);
                if stack.len() > self.prune_limit {
                    Self::prune(stack, &self.members, assoc, &mut self.present);
                }
            }
        }
    }

    /// Drop entries that are resident in no member (for every member,
    /// `assoc` or more same-class lines are more recent). Such entries can
    /// never be re-promoted without a fresh miss, and removing them never
    /// changes an outcome: any line below them already saturates the same
    /// `>= assoc` distance test through the entries that killed them.
    fn prune(
        stack: &mut VecDeque<Entry>,
        members: &[Member],
        assoc: u32,
        present: &mut HashSet<u64>,
    ) {
        let class_offsets: Vec<usize> = members
            .iter()
            .scan(0usize, |acc, m| {
                let off = *acc;
                *acc += m.ratio as usize;
                Some(off)
            })
            .collect();
        let total_classes: usize = members.iter().map(|m| m.ratio as usize).sum();
        let mut seen = vec![0u32; total_classes];
        stack.retain(|e| {
            let mut live = false;
            for (k, m) in members.iter().enumerate() {
                let class = class_offsets[k] + (e.q % m.ratio) as usize;
                if seen[class] < assoc {
                    live = true;
                }
                seen[class] += 1;
            }
            if !live {
                present.remove(&e.line);
            }
            live
        });
    }
}

/// One simulated capacity: either a member of a shared stack chain or a
/// standalone set-associative model (set count incommensurate with every
/// chain base).
#[derive(Debug)]
enum Chain {
    Single { cap: u64, cache: Cache },
    Stacked(StackChain),
}

/// Per-capacity counter bundle collected by [`CapacitySweepSim::finish`].
#[derive(Debug, Clone, Copy)]
struct CapCounters {
    hits: u64,
    misses: u64,
    writebacks: u64,
    write_hits: u64,
    write_misses: u64,
}

/// Exact single-pass simulator for several L2 capacities sharing one line
/// size and associativity. Feed it each access once; [`finish`] returns
/// one [`SimResult`] per requested capacity, bit-identical to running
/// [`simulate`] separately at that capacity.
///
/// [`finish`]: CapacitySweepSim::finish
#[derive(Debug)]
pub struct CapacitySweepSim {
    line: u64,
    /// Capacities in caller order (duplicates allowed).
    caps: Vec<u64>,
    chains: Vec<Chain>,
    accesses: u64,
}

impl CapacitySweepSim {
    pub fn new(line: u64, assoc: u64, capacities: &[u64]) -> CapacitySweepSim {
        assert!(line > 0 && assoc > 0, "degenerate cache geometry");
        let mut uniq: Vec<u64> = capacities.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        // Group ascending by set-count divisibility: the first (smallest)
        // capacity of each group is the chain base.
        let mut groups: Vec<(u64, Vec<u64>)> = Vec::new();
        for &cap in &uniq {
            assert!(
                cap % (line * assoc) == 0 && cap > 0,
                "cache geometry: swept capacity {cap} B is not a whole number of {assoc}-way \
                 sets of {line} B lines ({} B would be dropped)",
                cap % (line * assoc)
            );
            let sets = (cap / line) / assoc;
            match groups.iter_mut().find(|(base, _)| sets % *base == 0) {
                Some((_, caps)) => caps.push(cap),
                None => groups.push((sets, vec![cap])),
            }
        }
        let chains = groups
            .into_iter()
            .map(|(base_sets, caps)| {
                if caps.len() == 1 {
                    Chain::Single {
                        cap: caps[0],
                        cache: Cache::new(caps[0], line, assoc),
                    }
                } else {
                    Chain::Stacked(StackChain::new(base_sets, line, assoc, &caps))
                }
            })
            .collect();
        CapacitySweepSim {
            line,
            caps: capacities.to_vec(),
            chains,
            accesses: 0,
        }
    }

    /// Simulate one access (byte address) against every capacity.
    pub fn access(&mut self, addr: u64, write: bool) {
        let line_addr = addr / self.line;
        for chain in &mut self.chains {
            match chain {
                Chain::Single { cache, .. } => {
                    cache.access(addr, write);
                }
                Chain::Stacked(sc) => sc.access(line_addr, write),
            }
        }
        self.accesses += 1;
    }

    /// Per-capacity results, aligned with the `capacities` given to `new`.
    pub fn finish(self) -> Vec<SimResult> {
        let CapacitySweepSim {
            caps,
            chains,
            accesses,
            ..
        } = self;
        let mut per_cap: HashMap<u64, CapCounters> = HashMap::new();
        for chain in chains {
            match chain {
                Chain::Single { cap, cache } => {
                    per_cap.insert(
                        cap,
                        CapCounters {
                            hits: cache.hits,
                            misses: cache.misses,
                            writebacks: cache.writebacks,
                            write_hits: cache.write_hits,
                            write_misses: cache.write_misses,
                        },
                    );
                }
                Chain::Stacked(sc) => {
                    for m in sc.members {
                        per_cap.insert(
                            m.cap,
                            CapCounters {
                                hits: m.hits,
                                misses: m.misses,
                                writebacks: m.writebacks,
                                write_hits: m.write_hits,
                                write_misses: m.write_misses,
                            },
                        );
                    }
                }
            }
        }
        caps.iter()
            .map(|&cap| {
                let c = per_cap[&cap];
                SimResult {
                    l2_bytes: cap,
                    l2_accesses: accesses,
                    l2_hits: c.hits,
                    l2_misses: c.misses,
                    writebacks: c.writebacks,
                    l2_write_hits: c.write_hits,
                    l2_write_misses: c.write_misses,
                    // The sweep is write-back/write-allocate by
                    // construction: every write touches the array, every
                    // miss fills, DRAM writes are exactly the writebacks.
                    l2_array_writes: c.write_hits + c.write_misses,
                    dram_fills: c.misses,
                    dram_writes: c.writebacks,
                    warmup_accesses: 0,
                    // The Mattson sweep is fault-free by construction
                    // (fault injection requires a concrete replay).
                    faults_corrected: 0,
                    faults_detected: 0,
                    faults_silent: 0,
                    retired_ways: 0,
                    max_line_writes: 0,
                    // Sweeps never run a backend; zero stats match the
                    // fixed-latency direct simulation bit-exactly.
                    dram: DramStats::default(),
                    l1: None,
                }
            })
            .collect()
    }
}

/// One point of the Fig 7 sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    pub result: SimResult,
    /// DRAM-access reduction vs the 3MB baseline (%), Fig 7's y-axis.
    pub dram_reduction_pct: f64,
}

fn reductions(results: Vec<SimResult>) -> Vec<SweepPoint> {
    let baseline = results[0].dram_accesses() as f64;
    results
        .into_iter()
        .map(|result| SweepPoint {
            result,
            dram_reduction_pct: 100.0 * (1.0 - result.dram_accesses() as f64 / baseline),
        })
        .collect()
}

/// The Fig 7 experiment: run the trace at the baseline 3MB plus the given
/// capacities and report the percentage DRAM-access reduction of each.
/// The whole sweep is one pass over the trace (which may be a streaming
/// [`TraceGen`](super::trace::TraceGen) — nothing is materialized).
pub fn capacity_sweep(
    trace: impl IntoIterator<Item = Access>,
    capacities: &[u64],
) -> Vec<SweepPoint> {
    let base_cfg = GpuConfig::gtx_1080_ti();
    let mut caps: Vec<u64> = Vec::with_capacity(capacities.len() + 1);
    caps.push(base_cfg.l2_bytes);
    caps.extend_from_slice(capacities);
    let mut sim = CapacitySweepSim::new(base_cfg.l2_line, base_cfg.l2_assoc, &caps);
    for a in trace {
        sim.access(a.addr, a.write);
    }
    reductions(sim.finish())
}

/// [`capacity_sweep`] under an explicit cache configuration. The default
/// configuration without warmup takes the single-pass stack-distance
/// path; anything else (non-LRU replacement, through/bypass writes, L1
/// on, or a warmup prefix) compresses and partitions the trace **once**
/// — the shard modulus is the gcd of every swept capacity's valid
/// grouping, so one partition serves all capacities — and replays each
/// capacity through the set-sharded parallel simulator. `warmup_frac` is
/// the fraction of the trace replayed as cache warmup before counting.
pub fn capacity_sweep_config(
    trace: impl IntoIterator<Item = Access>,
    capacities: &[u64],
    cache: CacheConfig,
    warmup_frac: Option<f64>,
    max_shards: usize,
) -> Vec<SweepPoint> {
    if cache.is_default() && warmup_frac.is_none() {
        return capacity_sweep(trace, capacities);
    }
    let base_cfg = GpuConfig::gtx_1080_ti();
    let mut caps: Vec<u64> = Vec::with_capacity(capacities.len() + 1);
    caps.push(base_cfg.l2_bytes);
    caps.extend_from_slice(capacities);
    // Compress once; every per-capacity replay decodes the same blocks.
    let all = CompressedTrace::from_accesses(trace);
    let warmup = warmup_frac.map_or(0, |f| (f * all.len() as f64) as u64);
    let group = caps
        .iter()
        .map(|&cap| shard_group(&base_cfg.clone().with_l2(cap), cache))
        .fold(0, gcd);
    let shards = group.min(max_shards.max(1) as u64).max(1) as usize;
    let results: Vec<SimResult> = if shards <= 1 {
        caps.iter()
            .map(|&cap| {
                simulate_config(all.iter(), &base_cfg.clone().with_l2(cap), cache, warmup)
            })
            .collect()
    } else {
        let parts =
            ShardedTrace::partition_by(all.iter(), base_cfg.l2_line, group, shards, warmup);
        caps.iter()
            .map(|&cap| {
                parts.replay(
                    &base_cfg.clone().with_l2(cap),
                    cache,
                    None,
                    &MemBackendConfig::FixedLatency,
                )
            })
            .collect()
    };
    reductions(results)
}

/// The paper's Fig 7 capacity set: the 3MB baseline doubled up to 24MB,
/// plus the two iso-area capacities (STT 7MB, SOT 10MB).
pub fn fig7_capacities() -> Vec<u64> {
    vec![6 * MB, 7 * MB, 10 * MB, 12 * MB, 24 * MB]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::trace::net_trace;
    use crate::util::rng::Rng;
    use crate::workloads::nets;

    #[test]
    fn dram_accesses_fall_monotonically_with_capacity() {
        let sweep = capacity_sweep(net_trace(&nets::alexnet(), 4), &fig7_capacities());
        for w in sweep.windows(2) {
            assert!(
                w[1].result.dram_accesses() <= w[0].result.dram_accesses(),
                "non-monotone: {:?} -> {:?}",
                w[0].result,
                w[1].result
            );
        }
    }

    #[test]
    fn fig7_reductions_in_paper_band() {
        // Paper: 14.6% at the STT iso-area 7MB, 19.8% at the SOT 10MB.
        // The trace substrate differs from the authors' GPGPU-Sim+DarkNet
        // stack, so we require the band, not the exact point.
        let sweep = capacity_sweep(net_trace(&nets::alexnet(), 4), &fig7_capacities());
        let at = |cap: u64| {
            sweep
                .iter()
                .find(|p| p.result.l2_bytes == cap)
                .unwrap()
                .dram_reduction_pct
        };
        let stt = at(7 * MB);
        let sot = at(10 * MB);
        assert!((8.0..22.0).contains(&stt), "7MB reduction {stt}%");
        assert!((12.0..28.0).contains(&sot), "10MB reduction {sot}%");
        assert!(sot > stt, "more capacity, more reduction");
    }

    #[test]
    fn baseline_reduction_is_zero() {
        let sweep = capacity_sweep(net_trace(&nets::alexnet(), 4), &[]);
        assert_eq!(sweep.len(), 1);
        assert!(sweep[0].dram_reduction_pct.abs() < 1e-9);
    }

    #[test]
    fn hit_rate_rises_with_capacity() {
        let net = nets::alexnet();
        let small = simulate(net_trace(&net, 4), &GpuConfig::gtx_1080_ti());
        let big = simulate(net_trace(&net, 4), &GpuConfig::gtx_1080_ti().with_l2(24 * MB));
        assert!(big.l2_hit_rate() > small.l2_hit_rate());
        assert_eq!(big.l2_accesses, small.l2_accesses);
    }

    /// The tentpole equivalence guarantee: the single-pass sweep is
    /// bit-identical to direct per-capacity simulation at every Fig 7
    /// capacity, for real DNN traces (exercises both the shared-stack
    /// chain 3/6/12/24 MB and the standalone 7/10 MB members).
    #[test]
    fn sweep_matches_direct_simulation_bit_exactly() {
        for (net, batch) in [(nets::alexnet(), 1), (nets::squeezenet(), 1)] {
            let trace: Vec<Access> = net_trace(&net, batch).collect();
            let sweep = capacity_sweep(trace.iter().copied(), &fig7_capacities());
            for p in &sweep {
                let cfg = GpuConfig::gtx_1080_ti().with_l2(p.result.l2_bytes);
                let direct = simulate(trace.iter().copied(), &cfg);
                assert_eq!(
                    p.result, direct,
                    "{} at {}B",
                    net.name, p.result.l2_bytes
                );
            }
        }
    }

    #[test]
    fn duplicate_and_unordered_capacities_align_with_input() {
        let mut rng = Rng::new(11);
        let caps = [24 * MB, 7 * MB, 24 * MB, 3 * MB];
        let mut sim = CapacitySweepSim::new(128, 16, &caps);
        for _ in 0..50_000 {
            sim.access(rng.gen_range(1 << 16) * 128, rng.chance(0.3));
        }
        let r = sim.finish();
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].l2_bytes, 24 * MB);
        assert_eq!(r[1].l2_bytes, 7 * MB);
        assert_eq!(r[2].l2_bytes, 24 * MB);
        assert_eq!(r[3].l2_bytes, 3 * MB);
        assert_eq!(r[0].l2_hits, r[2].l2_hits, "duplicate capacities agree");
        assert_eq!(r[0].writebacks, r[2].writebacks);
        assert!(r[0].l2_hits >= r[3].l2_hits, "24MB >= 3MB hits");
    }

    #[test]
    fn sharded_replay_matches_sequential_on_a_real_trace() {
        let net = nets::squeezenet();
        let trace: Vec<Access> = net_trace(&net, 1).collect();
        let gpu = GpuConfig::gtx_1080_ti();
        for cache in [
            CacheConfig::default(),
            CacheConfig { write: WritePolicy::WriteBypass, ..CacheConfig::default() },
            CacheConfig { replacement: Replacement::Srrip, l1: true, ..CacheConfig::default() },
        ] {
            let seq = simulate_config(trace.iter().copied(), &gpu, cache, 0);
            let par = simulate_sharded(trace.iter().copied(), &gpu, cache, 0, 8);
            assert_eq!(seq, par, "{}", cache.describe());
        }
    }

    #[test]
    fn sharded_trace_partitions_once_and_replays_exactly() {
        let net = nets::squeezenet();
        let trace: Vec<Access> = net_trace(&net, 1).collect();
        let gpu = GpuConfig::gtx_1080_ti();
        let cache = CacheConfig::default();
        let st = ShardedTrace::partition(trace.iter().copied(), &gpu, cache, 0, 8);
        assert_eq!(st.len(), trace.len());
        assert_eq!(st.num_shards(), 8);
        assert_eq!((0..8).map(|s| st.shard_len(s)).sum::<usize>(), trace.len());
        assert!(
            st.byte_len() < trace.len() * 16,
            "compressed shards beat the raw 16 B/access struct: {} B for {} accesses",
            st.byte_len(),
            trace.len()
        );
        let seq = simulate_config(trace.iter().copied(), &gpu, cache, 0);
        let a = st.replay(&gpu, cache, None, &MemBackendConfig::FixedLatency);
        let b = st.replay(&gpu, cache, None, &MemBackendConfig::FixedLatency);
        assert_eq!(a, seq, "compressed sharded replay is bit-identical");
        assert_eq!(b, seq, "replay is repeatable from one partition");
    }

    #[test]
    fn warmup_discards_the_prefix_but_keeps_state() {
        let net = nets::squeezenet();
        let trace: Vec<Access> = net_trace(&net, 1).collect();
        let gpu = GpuConfig::gtx_1080_ti();
        let warm = (trace.len() / 4) as u64;
        let full = simulate(trace.iter().copied(), &gpu);
        let warmed = simulate_config(trace.iter().copied(), &gpu, CacheConfig::default(), warm);
        assert_eq!(warmed.warmup_accesses, warm);
        assert_eq!(warmed.l2_accesses, full.l2_accesses - warm);
        assert!(warmed.l2_hits < full.l2_hits);
        // Warmed measurement is exactly the tail of the full run: replay
        // the prefix on a fresh hierarchy, reset, replay the rest.
        let mut h = Hierarchy::new(&gpu, CacheConfig::default());
        for a in &trace[..warm as usize] {
            h.access(a.addr, a.write);
        }
        h.start_measurement();
        for a in &trace[warm as usize..] {
            h.access(a.addr, a.write);
        }
        assert_eq!(h.finish(), warmed);
        // And the sharded path agrees with the sequential warmup exactly.
        let sharded =
            simulate_sharded(trace.iter().copied(), &gpu, CacheConfig::default(), warm, 8);
        assert_eq!(sharded, warmed);
    }

    #[test]
    fn l1_filters_reads_but_not_writes() {
        let net = nets::squeezenet();
        let gpu = GpuConfig::gtx_1080_ti();
        let off = simulate(net_trace(&net, 1), &gpu);
        let cache = CacheConfig { l1: true, ..CacheConfig::default() };
        let on = simulate_config(net_trace(&net, 1), &gpu, cache, 0);
        let l1 = on.l1.expect("L1 level simulated");
        assert_eq!(l1.accesses, off.l2_accesses, "hierarchy sees the full trace");
        assert!(l1.hits > 0, "the aggregate L1 captures short-distance reuse");
        assert!(on.l2_accesses < off.l2_accesses, "read hits are filtered");
        // Writes pass through: the L2 write mix is unchanged.
        assert_eq!(
            on.l2_write_hits + on.l2_write_misses,
            off.l2_write_hits + off.l2_write_misses
        );
    }

    #[test]
    fn policy_sweep_falls_back_to_replay_and_matches_shapes() {
        let net = nets::squeezenet();
        let caps = vec![6 * MB, 12 * MB];
        let cache = CacheConfig { write: WritePolicy::WriteThrough, ..CacheConfig::default() };
        let sweep = capacity_sweep_config(net_trace(&net, 1), &caps, cache, None, 4);
        assert_eq!(sweep.len(), 3, "baseline + 2 capacities");
        assert!(sweep[0].dram_reduction_pct.abs() < 1e-9);
        for p in &sweep {
            assert_eq!(p.result.writebacks, 0, "write-through never writes back");
            assert!(p.result.dram_writes > 0, "through traffic reaches DRAM");
        }
        // The swept replay is per-capacity exact: each point matches a
        // standalone simulation under the same config (incl. warmup).
        let warmed = capacity_sweep_config(net_trace(&net, 1), &caps, cache, Some(0.25), 4);
        let total = net_trace(&net, 1).count() as u64;
        let warm = (0.25 * total as f64) as u64;
        for p in &warmed {
            let gpu = GpuConfig::gtx_1080_ti().with_l2(p.result.l2_bytes);
            let direct = simulate_config(net_trace(&net, 1), &gpu, cache, warm);
            assert_eq!(p.result, direct, "at {}B", p.result.l2_bytes);
        }
        // Default config routes to the identical single-pass path.
        let a = capacity_sweep_config(net_trace(&net, 1), &caps, CacheConfig::default(), None, 4);
        let b = capacity_sweep(net_trace(&net, 1), &caps);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.result, y.result);
        }
    }

    #[test]
    fn fixed_backend_is_bit_identical_to_the_plain_entrypoints() {
        let net = nets::squeezenet();
        let trace: Vec<Access> = net_trace(&net, 1).collect();
        let gpu = GpuConfig::gtx_1080_ti();
        let plain = simulate(trace.iter().copied(), &gpu);
        let explicit = simulate_backend(
            trace.iter().copied(),
            &gpu,
            CacheConfig::default(),
            0,
            8,
            &MemBackendConfig::FixedLatency,
        );
        assert_eq!(plain, explicit);
        assert_eq!(explicit.dram, DramStats::default());
    }

    #[test]
    fn dram_backend_counts_match_the_fill_and_write_counters() {
        use crate::membackend::DramConfig;
        let net = nets::squeezenet();
        let trace: Vec<Access> = net_trace(&net, 1).collect();
        let gpu = GpuConfig::gtx_1080_ti();
        let backend = MemBackendConfig::Dram(DramConfig::default());
        for cache in [
            CacheConfig::default(),
            CacheConfig { write: WritePolicy::WriteThrough, ..CacheConfig::default() },
            CacheConfig { write: WritePolicy::WriteBypass, ..CacheConfig::default() },
        ] {
            let r = simulate_backend(trace.iter().copied(), &gpu, cache, 0, 1, &backend);
            assert_eq!(r.dram.reads, r.dram_fills, "{}", cache.describe());
            assert_eq!(r.dram.writes, r.dram_writes, "{}", cache.describe());
            assert_eq!(
                r.dram.row_hits + r.dram.row_misses + r.dram.row_conflicts,
                r.dram.accesses(),
                "every access classifies into exactly one row outcome"
            );
            let per_channel: u64 = r.dram.channel_accesses.iter().sum();
            assert_eq!(per_channel, r.dram.accesses());
            // L2 counters are untouched by the observing backend.
            let base = simulate_config(trace.iter().copied(), &gpu, cache, 0);
            assert_eq!((r.l2_hits, r.l2_misses), (base.l2_hits, base.l2_misses));
        }
    }

    #[test]
    fn dram_backend_sharded_matches_sequential_bit_exactly() {
        use crate::membackend::DramConfig;
        let net = nets::squeezenet();
        let trace: Vec<Access> = net_trace(&net, 1).collect();
        let gpu = GpuConfig::gtx_1080_ti();
        let backend = MemBackendConfig::Dram(DramConfig::default());
        let warm = (trace.len() / 5) as u64;
        let seq = simulate_backend(
            trace.iter().copied(),
            &gpu,
            CacheConfig::default(),
            warm,
            1,
            &backend,
        );
        assert!(seq.dram.accesses() > 0, "miss traffic reaches the model");
        for shards in [2usize, 3, 8] {
            let par = simulate_backend(
                trace.iter().copied(),
                &gpu,
                CacheConfig::default(),
                warm,
                shards,
                &backend,
            );
            assert_eq!(seq, par, "{shards} shards");
        }
    }

    #[test]
    fn grouped_replay_matches_per_candidate_simulation() {
        let net = nets::squeezenet();
        let trace: Vec<Access> = net_trace(&net, 1).collect();
        let gpu = GpuConfig::gtx_1080_ti();
        let configs: Vec<ReplayConfig> = [
            CacheConfig::default(),
            CacheConfig { write: WritePolicy::WriteBypass, ..CacheConfig::default() },
            CacheConfig { replacement: Replacement::Srrip, ..CacheConfig::default() },
            CacheConfig { l1: true, ..CacheConfig::default() },
        ]
        .into_iter()
        .map(|cache| ReplayConfig::new(gpu.clone(), cache))
        .collect();
        let warm = (trace.len() / 3) as u64;
        let grouped = simulate_group(trace.iter().copied(), &configs, warm, 8);
        assert_eq!(grouped.len(), configs.len());
        for (rc, got) in configs.iter().zip(&grouped) {
            let direct = simulate_full(
                trace.iter().copied(),
                &rc.config,
                rc.cache,
                warm,
                8,
                rc.faults,
                &rc.backend,
            );
            assert_eq!(*got, direct, "{}", rc.cache.describe());
        }
    }

    #[test]
    fn group_modulus_folds_member_geometries() {
        let base = GpuConfig::gtx_1080_ti();
        let one = [ReplayConfig::new(base.clone(), CacheConfig::default())];
        assert_eq!(group_modulus(&one), base.l2_sets());
        // 1 MB (512 sets) and 3 MB (1536 sets) share a gcd of 512.
        let mixed = [
            ReplayConfig::new(base.clone().with_l2(MB), CacheConfig::default()),
            ReplayConfig::new(base.clone(), CacheConfig::default()),
        ];
        assert_eq!(group_modulus(&mixed), 512);
        // An L1 member with mismatched line sizes collapses the group.
        let mut odd_line = base.clone();
        odd_line.l1_line = base.l2_line / 2;
        let collapsed = [ReplayConfig::new(
            odd_line,
            CacheConfig { l1: true, ..CacheConfig::default() },
        )];
        assert_eq!(group_modulus(&collapsed), 1);
    }

    #[test]
    fn grouped_replay_handles_empty_and_all_warmup_traces() {
        let gpu = GpuConfig::gtx_1080_ti();
        let configs = [
            ReplayConfig::new(gpu.clone(), CacheConfig::default()),
            ReplayConfig::new(
                gpu.clone(),
                CacheConfig { write: WritePolicy::WriteThrough, ..CacheConfig::default() },
            ),
        ];
        // Zero-access trace: one zeroed result per member.
        let empty = simulate_group(std::iter::empty(), &configs, 0, 4);
        assert_eq!(empty.len(), 2);
        for r in &empty {
            assert_eq!((r.l2_accesses, r.warmup_accesses), (0, 0));
        }
        // A warmup prefix covering the whole trace measures nothing but
        // still counts the prefix, exactly like the per-candidate path.
        let trace: Vec<Access> =
            (0..100u64).map(|i| Access { addr: i * 128, write: i % 2 == 0 }).collect();
        let all_warm = simulate_group(trace.iter().copied(), &configs, 100, 4);
        for (rc, got) in configs.iter().zip(&all_warm) {
            let direct = simulate_full(
                trace.iter().copied(),
                &rc.config,
                rc.cache,
                100,
                4,
                rc.faults,
                &rc.backend,
            );
            assert_eq!(*got, direct);
            assert_eq!((got.l2_accesses, got.warmup_accesses), (0, 100));
        }
    }

    #[test]
    fn bypass_cuts_array_writes_on_streaming_workloads() {
        // The NVM story: im2col conv traces stream large write regions
        // through the L2; bypassing write misses slashes array writes.
        let net = nets::alexnet();
        let gpu = GpuConfig::gtx_1080_ti();
        let wb = simulate(net_trace(&net, 4), &gpu);
        let byp = simulate_config(
            net_trace(&net, 4),
            &gpu,
            CacheConfig { write: WritePolicy::WriteBypass, ..CacheConfig::default() },
            0,
        );
        assert!(
            byp.l2_array_writes < wb.l2_array_writes / 2,
            "bypass {} vs wb {}",
            byp.l2_array_writes,
            wb.l2_array_writes
        );
        assert!(byp.dram_fills < wb.dram_fills, "no write-miss fills");
    }
}
