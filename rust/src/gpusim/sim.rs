//! The trace-driven simulation loop and the Fig 7 capacity sweep.

use super::cache::Cache;
use super::config::GpuConfig;
use super::trace::Access;
use crate::util::pool::par_map;
use crate::util::units::MB;

/// Result of running one trace through one cache configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    /// L2 capacity simulated (bytes).
    pub l2_bytes: u64,
    pub l2_accesses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub writebacks: u64,
}

impl SimResult {
    /// DRAM transactions: every L2 miss fetches a line, every dirty
    /// eviction writes one back.
    pub fn dram_accesses(&self) -> u64 {
        self.l2_misses + self.writebacks
    }

    pub fn l2_hit_rate(&self) -> f64 {
        self.l2_hits as f64 / self.l2_accesses.max(1) as f64
    }
}

/// Run `trace` through the shared L2 of `config`.
pub fn simulate(trace: &[Access], config: &GpuConfig) -> SimResult {
    let mut l2 = Cache::new(config.l2_bytes, config.l2_line, config.l2_assoc);
    for a in trace {
        l2.access(a.addr, a.write);
    }
    SimResult {
        l2_bytes: config.l2_bytes,
        l2_accesses: l2.accesses(),
        l2_hits: l2.hits,
        l2_misses: l2.misses,
        writebacks: l2.writebacks,
    }
}

/// One point of the Fig 7 sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    pub result: SimResult,
    /// DRAM-access reduction vs the 3MB baseline (%), Fig 7's y-axis.
    pub dram_reduction_pct: f64,
}

/// The Fig 7 experiment: run the trace at the baseline 3MB plus the given
/// capacities and report the percentage DRAM-access reduction of each.
/// Capacities are simulated in parallel (the trace is shared read-only).
pub fn capacity_sweep(trace: &[Access], capacities: &[u64]) -> Vec<SweepPoint> {
    let base_cfg = GpuConfig::gtx_1080_ti();
    let mut caps: Vec<u64> = Vec::with_capacity(capacities.len() + 1);
    caps.push(3 * MB);
    caps.extend_from_slice(capacities);
    let results = par_map(&caps, |&cap| {
        simulate(trace, &base_cfg.clone().with_l2(cap))
    });
    let baseline = results[0].dram_accesses() as f64;
    results
        .into_iter()
        .map(|result| SweepPoint {
            result,
            dram_reduction_pct: 100.0 * (1.0 - result.dram_accesses() as f64 / baseline),
        })
        .collect()
}

/// The paper's Fig 7 capacity set: the 3MB baseline doubled up to 24MB,
/// plus the two iso-area capacities (STT 7MB, SOT 10MB).
pub fn fig7_capacities() -> Vec<u64> {
    vec![6 * MB, 7 * MB, 10 * MB, 12 * MB, 24 * MB]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::trace::dnn_trace;
    use crate::workloads::nets;

    fn alexnet_trace() -> Vec<Access> {
        dnn_trace(&nets::alexnet(), 4)
    }

    #[test]
    fn dram_accesses_fall_monotonically_with_capacity() {
        let trace = alexnet_trace();
        let sweep = capacity_sweep(&trace, &fig7_capacities());
        for w in sweep.windows(2) {
            assert!(
                w[1].result.dram_accesses() <= w[0].result.dram_accesses(),
                "non-monotone: {:?} -> {:?}",
                w[0].result,
                w[1].result
            );
        }
    }

    #[test]
    fn fig7_reductions_in_paper_band() {
        // Paper: 14.6% at the STT iso-area 7MB, 19.8% at the SOT 10MB.
        // The trace substrate differs from the authors' GPGPU-Sim+DarkNet
        // stack, so we require the band, not the exact point.
        let trace = alexnet_trace();
        let sweep = capacity_sweep(&trace, &fig7_capacities());
        let at = |cap: u64| {
            sweep
                .iter()
                .find(|p| p.result.l2_bytes == cap)
                .unwrap()
                .dram_reduction_pct
        };
        let stt = at(7 * MB);
        let sot = at(10 * MB);
        assert!((8.0..22.0).contains(&stt), "7MB reduction {stt}%");
        assert!((12.0..28.0).contains(&sot), "10MB reduction {sot}%");
        assert!(sot > stt, "more capacity, more reduction");
    }

    #[test]
    fn baseline_reduction_is_zero() {
        let trace = alexnet_trace();
        let sweep = capacity_sweep(&trace, &[]);
        assert_eq!(sweep.len(), 1);
        assert!(sweep[0].dram_reduction_pct.abs() < 1e-9);
    }

    #[test]
    fn hit_rate_rises_with_capacity() {
        let trace = alexnet_trace();
        let small = simulate(&trace, &GpuConfig::gtx_1080_ti());
        let big = simulate(&trace, &GpuConfig::gtx_1080_ti().with_l2(24 * MB));
        assert!(big.l2_hit_rate() > small.l2_hit_rate());
        assert_eq!(big.l2_accesses, small.l2_accesses);
    }
}
