//! The trace-driven simulation loop and the Fig 7 capacity sweep.
//!
//! The sweep is a **single-pass multi-capacity** simulation: one traversal
//! of the (streamed) trace computes exact hits/misses/writebacks for every
//! capacity at once via per-set LRU recency stacks (Mattson's stack
//! algorithm generalized to set-associative caches). All swept capacities
//! share the L2 line size and associativity, so each capacity only changes
//! the set count; capacities whose set counts are integer multiples of a
//! common base share one stack walk — a line's LRU stack distance within a
//! member's set is the number of more-recently-touched distinct lines of
//! the same residue class, and the access hits iff that distance is below
//! the associativity. Capacities with incommensurate set counts (7 MB and
//! 10 MB in the Fig 7 sweep) fall back to a plain set-associative model,
//! still fed by the same single trace traversal.
//!
//! Versus the old replay-per-capacity loop this turns O(trace × capacities)
//! work + O(trace) memory into one O(trace) pass + O(working set) memory,
//! and lets trace generation fuse with simulation (no materialized
//! `Vec<Access>`).

use std::collections::{HashMap, HashSet, VecDeque};

use super::cache::Cache;
use super::config::GpuConfig;
use super::trace::Access;
use crate::util::units::MB;

/// Result of running one trace through one cache configuration.
#[derive(Debug, Clone, Copy)]
pub struct SimResult {
    /// L2 capacity simulated (bytes).
    pub l2_bytes: u64,
    pub l2_accesses: u64,
    pub l2_hits: u64,
    pub l2_misses: u64,
    pub writebacks: u64,
}

impl SimResult {
    /// DRAM transactions: every L2 miss fetches a line, every dirty
    /// eviction writes one back.
    pub fn dram_accesses(&self) -> u64 {
        self.l2_misses + self.writebacks
    }

    pub fn l2_hit_rate(&self) -> f64 {
        self.l2_hits as f64 / self.l2_accesses.max(1) as f64
    }
}

/// Run `trace` through the shared L2 of `config`.
pub fn simulate(trace: impl IntoIterator<Item = Access>, config: &GpuConfig) -> SimResult {
    let mut l2 = Cache::new(config.l2_bytes, config.l2_line, config.l2_assoc);
    for a in trace {
        l2.access(a.addr, a.write);
    }
    SimResult {
        l2_bytes: config.l2_bytes,
        l2_accesses: l2.accesses(),
        l2_hits: l2.hits,
        l2_misses: l2.misses,
        writebacks: l2.writebacks,
    }
}

/// One resident-or-remembered line in a per-set recency stack.
#[derive(Debug, Clone, Copy)]
struct Entry {
    /// Full line address (identity).
    line: u64,
    /// `line / base_sets` — the part of the address that distinguishes the
    /// member set within the base set (residue classes of `q mod ratio`).
    q: u64,
    /// Dirty bit per chain member (bit k = member k's current residency).
    dirty: u32,
}

/// One capacity within a stack chain.
#[derive(Debug, Clone)]
struct Member {
    cap: u64,
    /// This member's set count divided by the chain's base set count.
    ratio: u64,
    /// `ratio - 1` when `ratio` is a power of two (XOR/AND class test).
    mask: u64,
    pow2: bool,
    hits: u64,
    misses: u64,
    writebacks: u64,
}

/// Capacities whose set counts are integer multiples of a common base,
/// resolved together by one recency-stack walk per access.
#[derive(Debug)]
struct StackChain {
    base_sets: u64,
    assoc: u32,
    members: Vec<Member>,
    /// One MRU-first recency stack per base set.
    stacks: Vec<VecDeque<Entry>>,
    /// Lines currently held in some stack (gates the stale-duplicate scan).
    present: HashSet<u64>,
    /// Stack length that triggers a dead-entry prune (2× the resident
    /// bound `assoc · Σ ratio`, so pruning amortizes to O(1) per access).
    prune_limit: usize,
    /// Scratch: per-member match count for the current walk.
    counts: Vec<u32>,
    /// Scratch: per-member residue of the current line (`q mod ratio`).
    residue: Vec<u64>,
}

impl StackChain {
    fn new(base_sets: u64, line: u64, assoc: u64, caps: &[u64]) -> StackChain {
        assert!(
            caps.len() <= 31,
            "stack chain dirty mask holds at most 31 members"
        );
        let members: Vec<Member> = caps
            .iter()
            .map(|&cap| {
                let sets = (cap / line) / assoc;
                assert!(sets % base_sets == 0 && sets >= base_sets, "not a chain member");
                let ratio = sets / base_sets;
                Member {
                    cap,
                    ratio,
                    mask: ratio - 1,
                    pow2: ratio.is_power_of_two(),
                    hits: 0,
                    misses: 0,
                    writebacks: 0,
                }
            })
            .collect();
        let resident_bound: usize =
            assoc as usize * members.iter().map(|m| m.ratio as usize).sum::<usize>();
        StackChain {
            base_sets,
            assoc: assoc as u32,
            stacks: vec![VecDeque::new(); base_sets as usize],
            present: HashSet::new(),
            prune_limit: 2 * resident_bound + 8,
            counts: vec![0; members.len()],
            residue: vec![0; members.len()],
            members,
        }
    }

    /// One access to `line` (a line address, not a byte address).
    ///
    /// Walks the line's base-set recency stack front-to-back. For member k
    /// the access hits iff fewer than `assoc` distinct lines of the same
    /// `q mod ratio_k` class sit above the line; the `assoc`-th such line
    /// encountered is exactly the LRU way this access would evict on a
    /// miss, which is where writebacks (dirty evictions) are charged. The
    /// walk stops as soon as the line is found (remaining members hit) or
    /// every member has resolved to a miss.
    fn access(&mut self, line: u64, write: bool) {
        let assoc = self.assoc;
        let s0 = (line % self.base_sets) as usize;
        let q = line / self.base_sets;
        let nm = self.members.len();
        let all_mask: u32 = (1u32 << nm) - 1;
        for (k, m) in self.members.iter().enumerate() {
            self.counts[k] = 0;
            self.residue[k] = if m.pow2 { 0 } else { q % m.ratio };
        }
        let stack = &mut self.stacks[s0];

        let mut missed: u32 = 0;
        let mut found: Option<usize> = None;
        let mut i = 0usize;
        while i < stack.len() {
            if stack[i].line == line {
                found = Some(i);
                break;
            }
            let eq = stack[i].q;
            let mut newly_missed = 0u32;
            for (k, m) in self.members.iter_mut().enumerate() {
                let bit = 1u32 << k;
                if missed & bit != 0 {
                    continue;
                }
                let same_set = if m.pow2 {
                    (eq ^ q) & m.mask == 0
                } else {
                    eq % m.ratio == self.residue[k]
                };
                if same_set {
                    self.counts[k] += 1;
                    if self.counts[k] == assoc {
                        // `assoc` set-mates are more recent: member k
                        // misses, and this entry is the LRU way it evicts.
                        m.misses += 1;
                        if stack[i].dirty & bit != 0 {
                            m.writebacks += 1;
                        }
                        newly_missed |= bit;
                    }
                }
            }
            if newly_missed != 0 {
                // Evicted residencies end here; clear so a later re-fetch
                // starts clean.
                stack[i].dirty &= !newly_missed;
                missed |= newly_missed;
                if missed == all_mask {
                    // Every member misses. If a stale copy of `line` sits
                    // deeper (evicted everywhere, not yet pruned), drop it
                    // so entries stay unique.
                    if self.present.contains(&line) {
                        if let Some(off) =
                            stack.iter().skip(i + 1).position(|e| e.line == line)
                        {
                            stack.remove(i + 1 + off);
                        }
                    }
                    break;
                }
            }
            i += 1;
        }

        match found {
            Some(pos) => {
                let mut e = stack.remove(pos).expect("indexed within bounds");
                for (k, m) in self.members.iter_mut().enumerate() {
                    let bit = 1u32 << k;
                    if missed & bit != 0 {
                        // Miss already charged (victim observed above);
                        // this access starts a fresh residency.
                        if write {
                            e.dirty |= bit;
                        } else {
                            e.dirty &= !bit;
                        }
                    } else {
                        m.hits += 1;
                        if write {
                            e.dirty |= bit;
                        }
                    }
                }
                stack.push_front(e);
            }
            None => {
                for (k, m) in self.members.iter_mut().enumerate() {
                    if missed & (1u32 << k) == 0 {
                        // Fewer than `assoc` set-mates above: the member
                        // set still has a free way — miss, no eviction.
                        m.misses += 1;
                    }
                }
                let dirty = if write { all_mask } else { 0 };
                stack.push_front(Entry { line, q, dirty });
                self.present.insert(line);
                if stack.len() > self.prune_limit {
                    Self::prune(stack, &self.members, assoc, &mut self.present);
                }
            }
        }
    }

    /// Drop entries that are resident in no member (for every member,
    /// `assoc` or more same-class lines are more recent). Such entries can
    /// never be re-promoted without a fresh miss, and removing them never
    /// changes an outcome: any line below them already saturates the same
    /// `>= assoc` distance test through the entries that killed them.
    fn prune(
        stack: &mut VecDeque<Entry>,
        members: &[Member],
        assoc: u32,
        present: &mut HashSet<u64>,
    ) {
        let class_offsets: Vec<usize> = members
            .iter()
            .scan(0usize, |acc, m| {
                let off = *acc;
                *acc += m.ratio as usize;
                Some(off)
            })
            .collect();
        let total_classes: usize = members.iter().map(|m| m.ratio as usize).sum();
        let mut seen = vec![0u32; total_classes];
        stack.retain(|e| {
            let mut live = false;
            for (k, m) in members.iter().enumerate() {
                let class = class_offsets[k] + (e.q % m.ratio) as usize;
                if seen[class] < assoc {
                    live = true;
                }
                seen[class] += 1;
            }
            if !live {
                present.remove(&e.line);
            }
            live
        });
    }
}

/// One simulated capacity: either a member of a shared stack chain or a
/// standalone set-associative model (set count incommensurate with every
/// chain base).
#[derive(Debug)]
enum Chain {
    Single { cap: u64, cache: Cache },
    Stacked(StackChain),
}

/// Exact single-pass simulator for several L2 capacities sharing one line
/// size and associativity. Feed it each access once; [`finish`] returns
/// one [`SimResult`] per requested capacity, bit-identical to running
/// [`simulate`] separately at that capacity.
///
/// [`finish`]: CapacitySweepSim::finish
#[derive(Debug)]
pub struct CapacitySweepSim {
    line: u64,
    /// Capacities in caller order (duplicates allowed).
    caps: Vec<u64>,
    chains: Vec<Chain>,
    accesses: u64,
}

impl CapacitySweepSim {
    pub fn new(line: u64, assoc: u64, capacities: &[u64]) -> CapacitySweepSim {
        assert!(line > 0 && assoc > 0, "degenerate cache geometry");
        let mut uniq: Vec<u64> = capacities.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        // Group ascending by set-count divisibility: the first (smallest)
        // capacity of each group is the chain base.
        let mut groups: Vec<(u64, Vec<u64>)> = Vec::new();
        for &cap in &uniq {
            let sets = (cap / line) / assoc;
            assert!(sets >= 1, "capacity {cap} below one set");
            match groups.iter_mut().find(|(base, _)| sets % *base == 0) {
                Some((_, caps)) => caps.push(cap),
                None => groups.push((sets, vec![cap])),
            }
        }
        let chains = groups
            .into_iter()
            .map(|(base_sets, caps)| {
                if caps.len() == 1 {
                    Chain::Single {
                        cap: caps[0],
                        cache: Cache::new(caps[0], line, assoc),
                    }
                } else {
                    Chain::Stacked(StackChain::new(base_sets, line, assoc, &caps))
                }
            })
            .collect();
        CapacitySweepSim {
            line,
            caps: capacities.to_vec(),
            chains,
            accesses: 0,
        }
    }

    /// Simulate one access (byte address) against every capacity.
    pub fn access(&mut self, addr: u64, write: bool) {
        let line_addr = addr / self.line;
        for chain in &mut self.chains {
            match chain {
                Chain::Single { cache, .. } => {
                    cache.access(addr, write);
                }
                Chain::Stacked(sc) => sc.access(line_addr, write),
            }
        }
        self.accesses += 1;
    }

    /// Per-capacity results, aligned with the `capacities` given to `new`.
    pub fn finish(self) -> Vec<SimResult> {
        let CapacitySweepSim {
            caps,
            chains,
            accesses,
            ..
        } = self;
        let mut per_cap: HashMap<u64, (u64, u64, u64)> = HashMap::new();
        for chain in chains {
            match chain {
                Chain::Single { cap, cache } => {
                    per_cap.insert(cap, (cache.hits, cache.misses, cache.writebacks));
                }
                Chain::Stacked(sc) => {
                    for m in sc.members {
                        per_cap.insert(m.cap, (m.hits, m.misses, m.writebacks));
                    }
                }
            }
        }
        caps.iter()
            .map(|&cap| {
                let (l2_hits, l2_misses, writebacks) = per_cap[&cap];
                SimResult {
                    l2_bytes: cap,
                    l2_accesses: accesses,
                    l2_hits,
                    l2_misses,
                    writebacks,
                }
            })
            .collect()
    }
}

/// One point of the Fig 7 sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    pub result: SimResult,
    /// DRAM-access reduction vs the 3MB baseline (%), Fig 7's y-axis.
    pub dram_reduction_pct: f64,
}

/// The Fig 7 experiment: run the trace at the baseline 3MB plus the given
/// capacities and report the percentage DRAM-access reduction of each.
/// The whole sweep is one pass over the trace (which may be a streaming
/// [`TraceGen`](super::trace::TraceGen) — nothing is materialized).
pub fn capacity_sweep(
    trace: impl IntoIterator<Item = Access>,
    capacities: &[u64],
) -> Vec<SweepPoint> {
    let base_cfg = GpuConfig::gtx_1080_ti();
    let mut caps: Vec<u64> = Vec::with_capacity(capacities.len() + 1);
    caps.push(base_cfg.l2_bytes);
    caps.extend_from_slice(capacities);
    let mut sim = CapacitySweepSim::new(base_cfg.l2_line, base_cfg.l2_assoc, &caps);
    for a in trace {
        sim.access(a.addr, a.write);
    }
    let results = sim.finish();
    let baseline = results[0].dram_accesses() as f64;
    results
        .into_iter()
        .map(|result| SweepPoint {
            result,
            dram_reduction_pct: 100.0 * (1.0 - result.dram_accesses() as f64 / baseline),
        })
        .collect()
}

/// The paper's Fig 7 capacity set: the 3MB baseline doubled up to 24MB,
/// plus the two iso-area capacities (STT 7MB, SOT 10MB).
pub fn fig7_capacities() -> Vec<u64> {
    vec![6 * MB, 7 * MB, 10 * MB, 12 * MB, 24 * MB]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::trace::net_trace;
    use crate::util::rng::Rng;
    use crate::workloads::nets;

    #[test]
    fn dram_accesses_fall_monotonically_with_capacity() {
        let sweep = capacity_sweep(net_trace(&nets::alexnet(), 4), &fig7_capacities());
        for w in sweep.windows(2) {
            assert!(
                w[1].result.dram_accesses() <= w[0].result.dram_accesses(),
                "non-monotone: {:?} -> {:?}",
                w[0].result,
                w[1].result
            );
        }
    }

    #[test]
    fn fig7_reductions_in_paper_band() {
        // Paper: 14.6% at the STT iso-area 7MB, 19.8% at the SOT 10MB.
        // The trace substrate differs from the authors' GPGPU-Sim+DarkNet
        // stack, so we require the band, not the exact point.
        let sweep = capacity_sweep(net_trace(&nets::alexnet(), 4), &fig7_capacities());
        let at = |cap: u64| {
            sweep
                .iter()
                .find(|p| p.result.l2_bytes == cap)
                .unwrap()
                .dram_reduction_pct
        };
        let stt = at(7 * MB);
        let sot = at(10 * MB);
        assert!((8.0..22.0).contains(&stt), "7MB reduction {stt}%");
        assert!((12.0..28.0).contains(&sot), "10MB reduction {sot}%");
        assert!(sot > stt, "more capacity, more reduction");
    }

    #[test]
    fn baseline_reduction_is_zero() {
        let sweep = capacity_sweep(net_trace(&nets::alexnet(), 4), &[]);
        assert_eq!(sweep.len(), 1);
        assert!(sweep[0].dram_reduction_pct.abs() < 1e-9);
    }

    #[test]
    fn hit_rate_rises_with_capacity() {
        let net = nets::alexnet();
        let small = simulate(net_trace(&net, 4), &GpuConfig::gtx_1080_ti());
        let big = simulate(net_trace(&net, 4), &GpuConfig::gtx_1080_ti().with_l2(24 * MB));
        assert!(big.l2_hit_rate() > small.l2_hit_rate());
        assert_eq!(big.l2_accesses, small.l2_accesses);
    }

    /// The tentpole equivalence guarantee: the single-pass sweep is
    /// bit-identical to direct per-capacity simulation at every Fig 7
    /// capacity, for real DNN traces (exercises both the shared-stack
    /// chain 3/6/12/24 MB and the standalone 7/10 MB members).
    #[test]
    fn sweep_matches_direct_simulation_bit_exactly() {
        for (net, batch) in [(nets::alexnet(), 1), (nets::squeezenet(), 1)] {
            let trace: Vec<Access> = net_trace(&net, batch).collect();
            let sweep = capacity_sweep(trace.iter().copied(), &fig7_capacities());
            for p in &sweep {
                let cfg = GpuConfig::gtx_1080_ti().with_l2(p.result.l2_bytes);
                let direct = simulate(trace.iter().copied(), &cfg);
                assert_eq!(
                    p.result.l2_hits, direct.l2_hits,
                    "{} hits at {}B",
                    net.name, p.result.l2_bytes
                );
                assert_eq!(
                    p.result.l2_misses, direct.l2_misses,
                    "{} misses at {}B",
                    net.name, p.result.l2_bytes
                );
                assert_eq!(
                    p.result.writebacks, direct.writebacks,
                    "{} writebacks at {}B",
                    net.name, p.result.l2_bytes
                );
                assert_eq!(p.result.l2_accesses, direct.l2_accesses);
            }
        }
    }

    #[test]
    fn duplicate_and_unordered_capacities_align_with_input() {
        let mut rng = Rng::new(11);
        let caps = [24 * MB, 7 * MB, 24 * MB, 3 * MB];
        let mut sim = CapacitySweepSim::new(128, 16, &caps);
        for _ in 0..50_000 {
            sim.access(rng.gen_range(1 << 16) * 128, rng.chance(0.3));
        }
        let r = sim.finish();
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].l2_bytes, 24 * MB);
        assert_eq!(r[1].l2_bytes, 7 * MB);
        assert_eq!(r[2].l2_bytes, 24 * MB);
        assert_eq!(r[3].l2_bytes, 3 * MB);
        assert_eq!(r[0].l2_hits, r[2].l2_hits, "duplicate capacities agree");
        assert_eq!(r[0].writebacks, r[2].writebacks);
        assert!(r[0].l2_hits >= r[3].l2_hits, "24MB >= 3MB hits");
    }
}
