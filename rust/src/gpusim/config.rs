//! GPU model configuration — paper Table 4 (NVIDIA GTX 1080 Ti) — and the
//! cache-hierarchy configuration ([`CacheConfig`]) that selects the
//! simulated policies.

use super::cache::{Replacement, WritePolicy};
use crate::util::units::{KB, MB};

/// Table 4, verbatim.
#[derive(Debug, Clone)]
pub struct GpuConfig {
    /// Number of streaming multiprocessors.
    pub cores: u32,
    /// Threads per core.
    pub threads_per_core: u32,
    /// Registers per core.
    pub registers_per_core: u32,
    /// L1 data cache per core: capacity / line / associativity.
    pub l1_bytes: u64,
    pub l1_line: u64,
    pub l1_assoc: u64,
    /// Shared L2: capacity / line / associativity.
    pub l2_bytes: u64,
    pub l2_line: u64,
    pub l2_assoc: u64,
    /// Instruction cache (modeled for completeness; traces are data-only).
    pub icache_bytes: u64,
    /// Warp schedulers per core.
    pub schedulers_per_core: u32,
    /// Clocks (Hz).
    pub core_clock: f64,
    pub interconnect_clock: f64,
    pub l2_clock: f64,
    pub memory_clock: f64,
}

impl GpuConfig {
    /// The paper's GTX 1080 Ti configuration with a 3MB L2
    /// ("for GPGPU-Sim compatibility, we set L2 cache capacity to 3MB").
    pub fn gtx_1080_ti() -> GpuConfig {
        GpuConfig {
            cores: 28,
            threads_per_core: 2048,
            registers_per_core: 65536,
            l1_bytes: 48 * KB,
            l1_line: 128,
            l1_assoc: 6,
            l2_bytes: 3 * MB,
            l2_line: 128,
            l2_assoc: 16,
            icache_bytes: 8 * KB,
            schedulers_per_core: 4,
            core_clock: 1481.0e6,
            interconnect_clock: 2962.0e6,
            l2_clock: 1481.0e6,
            memory_clock: 2750.0e6,
        }
    }

    /// Same GPU with an enlarged L2 (the paper's iso-area what-if).
    pub fn with_l2(mut self, l2_bytes: u64) -> GpuConfig {
        self.l2_bytes = l2_bytes;
        self
    }

    /// L2 cycle time (s).
    pub fn l2_cycle(&self) -> f64 {
        1.0 / self.l2_clock
    }

    /// L2 set count.
    pub fn l2_sets(&self) -> u64 {
        (self.l2_bytes / self.l2_line) / self.l2_assoc
    }

    /// Aggregate L1 capacity across all SMs — the Table 4 `l1_*` fields
    /// modeled as one shared filter in front of the L2 (per-SM address
    /// interleaving is not simulated; the aggregate captures the capacity
    /// effect on the L2-visible stream).
    pub fn l1_aggregate_bytes(&self) -> u64 {
        self.cores as u64 * self.l1_bytes
    }

    /// Set count of the aggregate L1.
    pub fn l1_aggregate_sets(&self) -> u64 {
        (self.l1_aggregate_bytes() / self.l1_line) / self.l1_assoc
    }
}

/// Cache-hierarchy configuration: which policies the trace-driven
/// simulator runs, and whether the L1 level is simulated at all. This is
/// *data* — it rides in engine [`Query`](crate::engine::Query) values
/// (memo-cache keyed), `[cache]` descriptor sections, explore axes, and
/// the `--write-policy/--replacement/--l1` CLI flags. The default is
/// bit-identical to the seed simulator: true-LRU, write-back, L1 off.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct CacheConfig {
    /// L2 replacement policy.
    pub replacement: Replacement,
    /// L2 write policy.
    pub write: WritePolicy,
    /// Simulate the aggregate L1 in front of the L2 (reads that hit in L1
    /// never reach L2; writes pass through).
    pub l1: bool,
}

/// Parse an L1 on/off value — the one grammar shared by the `--l1` CLI
/// flag, `[space]` axes, and `[cache]` descriptor sections (next to
/// [`WritePolicy::parse`] and [`Replacement::parse`]).
pub fn parse_l1(s: &str) -> crate::Result<bool> {
    match s.trim().to_ascii_lowercase().as_str() {
        "on" | "true" | "1" | "yes" => Ok(true),
        "off" | "false" | "0" | "no" => Ok(false),
        other => Err(crate::util::err::msg(format!("l1: expected on/off, got {other:?}"))),
    }
}

/// Parse the `--faults on|off` CLI value (the global fault-injection
/// switch; see [`crate::reliability::set_faults_enabled`]).
pub fn parse_faults(s: &str) -> crate::Result<bool> {
    match s.trim().to_ascii_lowercase().as_str() {
        "on" | "true" | "1" | "yes" => Ok(true),
        "off" | "false" | "0" | "no" => Ok(false),
        other => Err(crate::util::err::msg(format!("faults: expected on/off, got {other:?}"))),
    }
}

impl CacheConfig {
    /// Compact human/CSV rendering (`lru/wb/l1-off`).
    pub fn describe(&self) -> String {
        format!(
            "{}/{}/l1-{}",
            self.replacement.name(),
            self.write.name(),
            if self.l1 { "on" } else { "off" }
        )
    }

    /// Whether this is the seed-equivalent default configuration.
    pub fn is_default(&self) -> bool {
        *self == CacheConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_values() {
        let g = GpuConfig::gtx_1080_ti();
        assert_eq!(g.cores, 28);
        assert_eq!(g.threads_per_core, 2048);
        assert_eq!(g.registers_per_core, 65536);
        assert_eq!(g.l1_bytes, 48 * KB);
        assert_eq!(g.l1_assoc, 6);
        assert_eq!(g.l2_bytes, 3 * MB);
        assert_eq!(g.l2_line, 128);
        assert_eq!(g.l2_assoc, 16);
        assert_eq!(g.schedulers_per_core, 4);
        assert!((g.core_clock - 1.481e9).abs() < 1.0);
    }

    #[test]
    fn with_l2_scales_capacity_only() {
        let g = GpuConfig::gtx_1080_ti().with_l2(24 * MB);
        assert_eq!(g.l2_bytes, 24 * MB);
        assert_eq!(g.cores, 28);
    }

    #[test]
    fn derived_set_counts_match_table4() {
        let g = GpuConfig::gtx_1080_ti();
        assert_eq!(g.l2_sets(), 1536, "3MB / 128B / 16-way");
        assert_eq!(g.l1_aggregate_bytes(), 28 * 48 * KB);
        assert_eq!(g.l1_aggregate_sets(), 1792, "28x48KB / 128B / 6-way");
    }

    #[test]
    fn cache_config_default_is_seed_equivalent() {
        let c = CacheConfig::default();
        assert!(c.is_default());
        assert_eq!(c.replacement, Replacement::Lru);
        assert_eq!(c.write, WritePolicy::WriteBack);
        assert!(!c.l1);
        assert_eq!(c.describe(), "lru/wb/l1-off");
        let custom = CacheConfig { write: WritePolicy::WriteBypass, ..c };
        assert!(!custom.is_default());
        assert_eq!(custom.describe(), "lru/bypass/l1-off");
    }
}
