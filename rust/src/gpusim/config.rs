//! GPU model configuration — paper Table 4 (NVIDIA GTX 1080 Ti).

use crate::util::units::{KB, MB};

/// Table 4, verbatim.
#[derive(Debug, Clone)]
pub struct GpuConfig {
    /// Number of streaming multiprocessors.
    pub cores: u32,
    /// Threads per core.
    pub threads_per_core: u32,
    /// Registers per core.
    pub registers_per_core: u32,
    /// L1 data cache per core: capacity / line / associativity.
    pub l1_bytes: u64,
    pub l1_line: u64,
    pub l1_assoc: u64,
    /// Shared L2: capacity / line / associativity.
    pub l2_bytes: u64,
    pub l2_line: u64,
    pub l2_assoc: u64,
    /// Instruction cache (modeled for completeness; traces are data-only).
    pub icache_bytes: u64,
    /// Warp schedulers per core.
    pub schedulers_per_core: u32,
    /// Clocks (Hz).
    pub core_clock: f64,
    pub interconnect_clock: f64,
    pub l2_clock: f64,
    pub memory_clock: f64,
}

impl GpuConfig {
    /// The paper's GTX 1080 Ti configuration with a 3MB L2
    /// ("for GPGPU-Sim compatibility, we set L2 cache capacity to 3MB").
    pub fn gtx_1080_ti() -> GpuConfig {
        GpuConfig {
            cores: 28,
            threads_per_core: 2048,
            registers_per_core: 65536,
            l1_bytes: 48 * KB,
            l1_line: 128,
            l1_assoc: 6,
            l2_bytes: 3 * MB,
            l2_line: 128,
            l2_assoc: 16,
            icache_bytes: 8 * KB,
            schedulers_per_core: 4,
            core_clock: 1481.0e6,
            interconnect_clock: 2962.0e6,
            l2_clock: 1481.0e6,
            memory_clock: 2750.0e6,
        }
    }

    /// Same GPU with an enlarged L2 (the paper's iso-area what-if).
    pub fn with_l2(mut self, l2_bytes: u64) -> GpuConfig {
        self.l2_bytes = l2_bytes;
        self
    }

    /// L2 cycle time (s).
    pub fn l2_cycle(&self) -> f64 {
        1.0 / self.l2_clock
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_values() {
        let g = GpuConfig::gtx_1080_ti();
        assert_eq!(g.cores, 28);
        assert_eq!(g.threads_per_core, 2048);
        assert_eq!(g.registers_per_core, 65536);
        assert_eq!(g.l1_bytes, 48 * KB);
        assert_eq!(g.l1_assoc, 6);
        assert_eq!(g.l2_bytes, 3 * MB);
        assert_eq!(g.l2_line, 128);
        assert_eq!(g.l2_assoc, 16);
        assert_eq!(g.schedulers_per_core, 4);
        assert!((g.core_clock - 1.481e9).abs() < 1.0);
    }

    #[test]
    fn with_l2_scales_capacity_only() {
        let g = GpuConfig::gtx_1080_ti().with_l2(24 * MB);
        assert_eq!(g.l2_bytes, 24 * MB);
        assert_eq!(g.cores, 28);
    }
}
