//! Delta/varint-compressed access-trace blocks — the streaming trace
//! representation the sharded replay engine holds in memory.
//!
//! A materialized [`Access`] costs 16 bytes; DNN traces are dominated by
//! short strides inside one region (im2col walks, GEMM tiles), so the
//! byte-address *delta* between consecutive accesses is small and
//! repetitive. Each access encodes as one varint of the zigzagged delta
//! with the write bit folded into the first byte:
//!
//! ```text
//! zz     = zigzag(addr - prev_addr)         (zigzag(d) = (d << 1) ^ (d >> 63),
//!                                            arithmetic shift, mod 2^64)
//! byte 0 = cont << 7 | zz[5:0] << 1 | write
//! byte k = cont << 7 | zz[6+7(k-1) : ...]   (LEB128 continuation, LSB first)
//! ```
//!
//! which lands at 1–3 bytes for typical strides (≈5–8× smaller than the
//! struct, measured per net in BENCH_hotpath's `bytes/access` records).
//! Every [`BLOCK_ACCESSES`] accesses the delta predictor resets to 0 and
//! the block's byte offset is recorded, so blocks decode independently —
//! [`CompressedTrace::iter_blocks`] can start mid-trace without decoding
//! the prefix.
//!
//! The encoding is **lossless for any `u64` address sequence** (deltas
//! wrap mod 2⁶⁴ and unwrap the same way; line-alignment is *not*
//! assumed), so the sharded replay's bit-exactness guarantee is
//! untouched: decoding yields the exact `Access` stream that was pushed,
//! pinned against the golden trace checksums in `tests/golden.rs`.
//!
//! Zero-access streams are first-class, not a caller obligation: an empty
//! trace encodes to zero bytes and zero blocks, every decode entry point
//! yields an empty stream, and out-of-range block indices panic loudly
//! instead of decoding garbage. The multi-configuration replay leans on
//! this — a shard whose set-residue class received no accesses round
//! trips as an empty block list.

use super::trace::Access;

/// Accesses per independently-decodable block (the delta predictor
/// resets at each block boundary).
pub const BLOCK_ACCESSES: usize = 8192;

/// A delta/varint-compressed `Access` stream (append-only; decode with
/// [`CompressedTrace::iter`]).
#[derive(Debug, Clone, Default)]
pub struct CompressedTrace {
    bytes: Vec<u8>,
    /// Accesses encoded.
    len: usize,
    /// Byte offset where each block starts (block `b` covers accesses
    /// `b * BLOCK_ACCESSES ..`).
    blocks: Vec<usize>,
    /// Encoder state: previous address (reset to 0 at block starts).
    prev_addr: u64,
}

impl CompressedTrace {
    /// An empty trace.
    pub fn new() -> CompressedTrace {
        CompressedTrace::default()
    }

    /// Append one access.
    #[inline]
    pub fn push(&mut self, a: Access) {
        if self.len % BLOCK_ACCESSES == 0 {
            self.blocks.push(self.bytes.len());
            self.prev_addr = 0;
        }
        let delta = a.addr.wrapping_sub(self.prev_addr);
        self.prev_addr = a.addr;
        // Zigzag the wrapped delta (interpreted as i64) so small negative
        // strides stay small. The write bit rides in the first byte next
        // to the low 6 zigzag bits, so a full 64-bit zz still fits.
        let zz = (delta << 1) ^ (((delta as i64) >> 63) as u64);
        let first = (((zz << 1) as u8) & 0x7e) | u8::from(a.write);
        let mut rest = zz >> 6;
        if rest == 0 {
            self.bytes.push(first);
        } else {
            self.bytes.push(first | 0x80);
            loop {
                let byte = (rest & 0x7f) as u8;
                rest >>= 7;
                if rest == 0 {
                    self.bytes.push(byte);
                    break;
                }
                self.bytes.push(byte | 0x80);
            }
        }
        self.len += 1;
    }

    /// Compress an entire access stream.
    pub fn from_accesses(accesses: impl IntoIterator<Item = Access>) -> CompressedTrace {
        let mut ct = CompressedTrace::new();
        for a in accesses {
            ct.push(a);
        }
        ct
    }

    /// Accesses encoded.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the trace holds no accesses.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Encoded size in bytes (the number BENCH_hotpath divides by
    /// [`CompressedTrace::len`] for its bytes/access record).
    pub fn byte_len(&self) -> usize {
        self.bytes.len()
    }

    /// Number of blocks (`len` rounded up to [`BLOCK_ACCESSES`]; zero for
    /// an empty trace).
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Decode the whole stream.
    pub fn iter(&self) -> Decoder<'_> {
        self.iter_blocks(0)
    }

    /// Decode from the start of block `b` (0-indexed) to the end of the
    /// stream; `b == num_blocks()` yields an empty decoder. Panics if
    /// `b` exceeds the block count.
    pub fn iter_blocks(&self, b: usize) -> Decoder<'_> {
        assert!(
            b <= self.blocks.len(),
            "block {b} out of range ({} blocks)",
            self.blocks.len()
        );
        if b == self.blocks.len() {
            return Decoder { bytes: &[], pos: 0, prev_addr: 0, remaining: 0, until_reset: 0 };
        }
        Decoder {
            bytes: &self.bytes,
            pos: self.blocks[b],
            prev_addr: 0,
            remaining: self.len - b * BLOCK_ACCESSES,
            until_reset: BLOCK_ACCESSES,
        }
    }

    /// Decode exactly block `b` (up to [`BLOCK_ACCESSES`] accesses) into
    /// `out`, clearing it first; returns the access count. This is the
    /// multi-configuration replay's decode-once primitive: one call per
    /// (shard, block), then every candidate hierarchy probes the same
    /// decoded buffer. `b == num_blocks()` decodes nothing — the only
    /// valid index into a zero-access trace — and a larger `b` panics
    /// like [`CompressedTrace::iter_blocks`].
    pub fn decode_block(&self, b: usize, out: &mut Vec<Access>) -> usize {
        out.clear();
        let n = self.len.saturating_sub(b * BLOCK_ACCESSES).min(BLOCK_ACCESSES);
        out.extend(self.iter_blocks(b).take(n));
        n
    }
}

impl<'a> IntoIterator for &'a CompressedTrace {
    type Item = Access;
    type IntoIter = Decoder<'a>;

    fn into_iter(self) -> Decoder<'a> {
        self.iter()
    }
}

/// Streaming decoder over a [`CompressedTrace`] — yields the exact
/// pushed `Access` sequence, one varint at a time, in (host) cache.
#[derive(Debug, Clone)]
pub struct Decoder<'a> {
    bytes: &'a [u8],
    pos: usize,
    prev_addr: u64,
    remaining: usize,
    /// Accesses left before the delta predictor resets (block boundary).
    until_reset: usize,
}

impl Iterator for Decoder<'_> {
    type Item = Access;

    #[inline]
    fn next(&mut self) -> Option<Access> {
        if self.remaining == 0 {
            return None;
        }
        if self.until_reset == 0 {
            self.prev_addr = 0;
            self.until_reset = BLOCK_ACCESSES;
        }
        let first = self.bytes[self.pos];
        self.pos += 1;
        let write = first & 1 == 1;
        let mut zz = u64::from((first >> 1) & 0x3f);
        if first & 0x80 != 0 {
            let mut shift = 6u32;
            loop {
                let byte = self.bytes[self.pos];
                self.pos += 1;
                zz |= u64::from(byte & 0x7f) << shift;
                if byte & 0x80 == 0 {
                    break;
                }
                shift += 7;
            }
        }
        // Un-zigzag: (zz >> 1) ^ -(zz & 1), in wrapping u64 arithmetic.
        let delta = (zz >> 1) ^ (zz & 1).wrapping_neg();
        let addr = self.prev_addr.wrapping_add(delta);
        self.prev_addr = addr;
        self.remaining -= 1;
        self.until_reset -= 1;
        Some(Access { addr, write })
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl ExactSizeIterator for Decoder<'_> {}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(accesses: &[Access]) {
        let ct = CompressedTrace::from_accesses(accesses.iter().copied());
        assert_eq!(ct.len(), accesses.len());
        let back: Vec<Access> = ct.iter().collect();
        assert_eq!(back, accesses, "decode must reproduce the pushed stream");
    }

    #[test]
    fn empty_roundtrip() {
        roundtrip(&[]);
        assert!(CompressedTrace::new().is_empty());
        assert_eq!(CompressedTrace::new().iter().count(), 0);
    }

    #[test]
    fn zero_access_trace_is_losslessly_empty_at_every_entry_point() {
        let ct = CompressedTrace::from_accesses(std::iter::empty());
        assert_eq!((ct.len(), ct.byte_len(), ct.num_blocks()), (0, 0, 0));
        assert_eq!(ct.iter().count(), 0);
        assert_eq!(ct.iter_blocks(0).count(), 0, "num_blocks() is a valid (empty) index");
        let mut buf = vec![Access { addr: 99, write: true }];
        assert_eq!(ct.decode_block(0, &mut buf), 0);
        assert!(buf.is_empty(), "decode_block clears stale contents");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_block_panics_loudly_on_an_empty_trace() {
        let mut buf = Vec::new();
        CompressedTrace::new().decode_block(1, &mut buf);
    }

    #[test]
    fn decode_block_matches_the_pushed_slice_per_block() {
        // Cover both a ragged tail and an exact multiple of the block
        // size (the boundary where a lazily-pushed final block must not
        // exist).
        for len in [3 * BLOCK_ACCESSES + 17, 2 * BLOCK_ACCESSES, 1, 0] {
            let accesses: Vec<Access> = (0..len as u64)
                .map(|i| Access { addr: (i * 37) % 9973 * 128, write: i % 5 == 0 })
                .collect();
            let ct = CompressedTrace::from_accesses(accesses.iter().copied());
            assert_eq!(ct.num_blocks(), len.div_ceil(BLOCK_ACCESSES), "len {len}");
            let mut buf = Vec::new();
            let mut decoded = Vec::new();
            for b in 0..ct.num_blocks() {
                let n = ct.decode_block(b, &mut buf);
                assert_eq!(n, buf.len());
                assert_eq!(
                    buf,
                    accesses[b * BLOCK_ACCESSES..(b * BLOCK_ACCESSES + n)],
                    "block {b} of len {len}"
                );
                decoded.extend_from_slice(&buf);
            }
            assert_eq!(decoded, accesses, "blockwise decode is lossless at len {len}");
        }
    }

    #[test]
    fn small_strides_roundtrip_in_one_or_two_bytes() {
        let accesses: Vec<Access> = (0..1000u64)
            .map(|i| Access { addr: 0x1_0000_0000 + i * 128, write: i % 3 == 0 })
            .collect();
        let ct = CompressedTrace::from_accesses(accesses.iter().copied());
        // First token carries the big base address; the other 999 are a
        // constant +128-byte stride = 2-byte varints.
        assert!(ct.byte_len() <= 6 + 999 * 2, "{} bytes", ct.byte_len());
        assert_eq!(ct.iter().collect::<Vec<_>>(), accesses);
    }

    #[test]
    fn extreme_and_backward_addresses_roundtrip() {
        roundtrip(&[
            Access { addr: 0, write: false },
            Access { addr: u64::MAX, write: true },
            Access { addr: 1, write: true },
            Access { addr: u64::MAX / 2, write: false },
            Access { addr: u64::MAX / 2 + 1, write: false },
            Access { addr: 0, write: true },
            Access { addr: 127, write: false }, // not line-aligned
        ]);
    }

    #[test]
    fn blocks_decode_independently() {
        let accesses: Vec<Access> = (0..3 * BLOCK_ACCESSES as u64 + 17)
            .map(|i| Access { addr: (i * 37) % 9973 * 128, write: i % 5 == 0 })
            .collect();
        let ct = CompressedTrace::from_accesses(accesses.iter().copied());
        assert_eq!(ct.num_blocks(), 4);
        for b in 0..ct.num_blocks() {
            let tail: Vec<Access> = ct.iter_blocks(b).collect();
            assert_eq!(tail, accesses[b * BLOCK_ACCESSES..], "block {b}");
        }
        assert_eq!(ct.iter_blocks(ct.num_blocks()).count(), 0, "one-past-end is empty");
    }

    #[test]
    fn decoder_reports_exact_length() {
        let accesses: Vec<Access> =
            (0..100u64).map(|i| Access { addr: i * 64, write: false }).collect();
        let ct = CompressedTrace::from_accesses(accesses.iter().copied());
        let mut it = ct.iter();
        assert_eq!(it.len(), 100);
        it.next();
        assert_eq!(it.len(), 99);
        assert_eq!(it.size_hint(), (99, Some(99)));
        // `take(warm)` splitting — how replay separates warmup from
        // measurement — sees the right elements.
        let warm: Vec<Access> = ct.iter().take(10).collect();
        assert_eq!(warm, accesses[..10]);
        let rest: Vec<Access> = ct.iter().skip(10).collect();
        assert_eq!(rest, accesses[10..]);
    }
}
