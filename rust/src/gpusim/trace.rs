//! Address-trace generation from DNN layer descriptors — streamed.
//!
//! Replays the memory behaviour of the Caffe/DarkNet execution the paper
//! fed to GPGPU-Sim: per conv layer an im2col materialization into a
//! shared column buffer, then a tiled sgemm (64×64 threadblock tiles, the
//! cutlass-era shape) whose loop order re-reads the column buffer once per
//! N-tile and the weight tile once per M-sweep; activations ping-pong
//! between two buffers. Addresses are emitted at L2-line (128B)
//! granularity, post-L1 (each distinct line once per tile-level
//! operation — intra-tile reuse is register/SMEM-resident anyway).
//!
//! The reuse distances this produces are the whole point: AlexNet's
//! column buffers and conv weight tensors sit in the 1.5–18 MB range, so
//! sweeping the L2 from 3 MB to 24 MB progressively converts their
//! re-reads from DRAM traffic into L2 hits — Fig 7's mechanism.
//!
//! Generation is **streaming**: [`dnn_trace`] returns [`TraceGen`], a
//! resumable state machine implementing `Iterator<Item = Access>`. The
//! trace is never materialized — memory stays O(tiles of the current
//! layer) for the queued region runs (a few hundred KB for VGG-16) versus
//! O(trace) for the old `Vec<Access>` (tens of millions of entries), and
//! generation fuses with simulation in a single pass.

use std::collections::VecDeque;

use crate::workloads::dnn::{Dnn, Layer};
use crate::workloads::memstats::ELEM_BYTES;

/// Threadblock GEMM tile edge (M and N) in elements.
pub const TB_TILE: u64 = 128;

/// L2 line size the trace is quantized to (bytes).
pub const LINE: u64 = 128;

/// One memory access (line-aligned address).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Access {
    pub addr: u64,
    pub write: bool,
}

/// Address-space regions (disjoint by construction).
const WEIGHT_BASE: u64 = 0x1_0000_0000;
const COL_BASE: u64 = 0x8_0000_0000;
const ACT_A_BASE: u64 = 0x10_0000_0000;
const ACT_B_BASE: u64 = 0x18_0000_0000;

/// A queued sequential region touch, expanded lazily one line at a time.
#[derive(Debug, Clone, Copy)]
struct Run {
    base: u64,
    bytes: u64,
    write: bool,
}

/// Streaming trace generator: a resumable state machine over the network's
/// layers. Each layer expands to a bounded queue of `Run`s (one per
/// im2col region or GEMM tile operand); `next()` walks the current run one
/// L2 line at a time.
pub struct TraceGen<'a> {
    net: &'a Dnn,
    batch: u64,
    /// Next layer to expand into `runs`.
    next_layer: usize,
    weight_off: u64,
    input_is_a: bool,
    runs: VecDeque<Run>,
    /// Current run: (run, total lines, next line index).
    cur: Option<(Run, u64, u64)>,
}

impl<'a> TraceGen<'a> {
    fn new(net: &'a Dnn, batch: u64) -> Self {
        TraceGen {
            net,
            batch,
            next_layer: 0,
            weight_off: 0,
            input_is_a: true,
            runs: VecDeque::new(),
            cur: None,
        }
    }

    /// Queue a sequential region touch, one access per line.
    fn push_region(&mut self, base: u64, bytes: u64, write: bool) {
        self.runs.push_back(Run { base, bytes, write });
    }

    /// Queue the tiled GEMM access pattern: `out[M,N] = a[M,K] × b[K,N]`,
    /// with `a` at `a_base` (col buffer / activations) and `b` at `b_base`
    /// (weights). Loop order: M-tiles outer (output-stationary row sweep,
    /// the standard GPU sgemm schedule). Consequences for reuse distance:
    /// the A row-tile is re-read per N-tile at a *short* distance (one
    /// inner iteration), while each B (weight) column-tile is re-read once
    /// per M-tile at a distance of roughly `|B| + n_tiles·|A-tile|` —
    /// for AlexNet's conv3–conv5 that is 3.5–7 MB, which is exactly the
    /// window the paper's 3→24 MB capacity sweep opens (Fig 7).
    fn push_gemm(&mut self, m: u64, n: u64, k: u64, a_base: u64, b_base: u64, out_base: u64) {
        let m_tiles = m.div_ceil(TB_TILE);
        let n_tiles = n.div_ceil(TB_TILE);
        let a_tile_bytes = TB_TILE * k * ELEM_BYTES;
        let b_tile_bytes = k * TB_TILE * ELEM_BYTES;
        let out_tile_bytes = TB_TILE * TB_TILE * ELEM_BYTES;
        for mt in 0..m_tiles {
            // Edge tiles are clamped to the actual matrix extent.
            let tm = (m - mt * TB_TILE).min(TB_TILE);
            for nt in 0..n_tiles {
                let tn = (n - nt * TB_TILE).min(TB_TILE);
                // Read A row-tile (re-read once per N-tile, short distance).
                self.push_region(a_base + mt * a_tile_bytes, tm * k * ELEM_BYTES, false);
                // Read B column-tile (re-read per M-tile, medium distance).
                self.push_region(b_base + nt * b_tile_bytes, k * tn * ELEM_BYTES, false);
                // Write the output tile.
                self.push_region(
                    out_base + (mt * n_tiles + nt) * out_tile_bytes,
                    tm * tn * ELEM_BYTES,
                    true,
                );
            }
        }
    }

    /// Expand the next layer into the run queue (advances the layer
    /// cursor, weight offset and activation ping-pong).
    fn enqueue_layer(&mut self) {
        let net = self.net;
        let layer = &net.layers[self.next_layer];
        self.next_layer += 1;
        let (in_base, out_base) = if self.input_is_a {
            (ACT_A_BASE, ACT_B_BASE)
        } else {
            (ACT_B_BASE, ACT_A_BASE)
        };
        let i_bytes = layer.input.numel() * self.batch * ELEM_BYTES;
        let o_bytes = layer.output.numel() * self.batch * ELEM_BYTES;
        let w_bytes = layer.weights() * ELEM_BYTES;
        match layer.layer {
            Layer::Conv {
                out_c,
                kernel,
                groups,
                ..
            } => {
                let m = self.batch * layer.output.h * layer.output.w;
                let n = out_c;
                let k = (layer.input.c / groups) * kernel * kernel;
                let a_base = if kernel > 1 {
                    // im2col: read the input, write the column buffer.
                    self.push_region(in_base, i_bytes, false);
                    self.push_region(COL_BASE, m * k * ELEM_BYTES, true);
                    COL_BASE
                } else {
                    in_base
                };
                let weight_base = WEIGHT_BASE + self.weight_off;
                self.push_gemm(m, n, k, a_base, weight_base, out_base);
            }
            Layer::Fc { out, .. } => {
                let m = self.batch;
                let n = out;
                let k = layer.input.numel();
                let weight_base = WEIGHT_BASE + self.weight_off;
                self.push_gemm(m, n, k, in_base, weight_base, out_base);
            }
            Layer::Pool { .. } | Layer::GlobalPool { .. } | Layer::Concat { .. } => {
                self.push_region(in_base, i_bytes, false);
                self.push_region(out_base, o_bytes, true);
            }
        }
        self.weight_off += w_bytes.div_ceil(LINE) * LINE;
        self.input_is_a = !self.input_is_a;
    }
}

impl Iterator for TraceGen<'_> {
    type Item = Access;

    fn next(&mut self) -> Option<Access> {
        loop {
            if let Some((run, lines, next)) = &mut self.cur {
                if *next < *lines {
                    let a = Access {
                        addr: run.base + *next * LINE,
                        write: run.write,
                    };
                    *next += 1;
                    return Some(a);
                }
                self.cur = None;
            }
            if let Some(run) = self.runs.pop_front() {
                self.cur = Some((run, run.bytes.div_ceil(LINE), 0));
                continue;
            }
            if self.next_layer >= self.net.layers.len() {
                return None;
            }
            self.enqueue_layer();
        }
    }
}

/// Stream the forward-pass trace of `net` at batch size `batch`.
pub fn dnn_trace(net: &Dnn, batch: u64) -> TraceGen<'_> {
    TraceGen::new(net, batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::nets;

    #[test]
    fn trace_is_nonempty_and_line_aligned() {
        let t: Vec<Access> = dnn_trace(&nets::alexnet(), 1).collect();
        assert!(t.len() > 100_000);
        assert!(t.iter().all(|a| a.addr % LINE == 0));
    }

    #[test]
    fn trace_contains_reads_and_writes() {
        let (mut writes, mut total) = (0usize, 0usize);
        for a in dnn_trace(&nets::squeezenet(), 1) {
            total += 1;
            writes += a.write as usize;
        }
        assert!(writes > 0 && writes < total);
    }

    #[test]
    fn regions_do_not_collide() {
        // Weight traffic must never alias the activation or col regions.
        for a in dnn_trace(&nets::alexnet(), 1) {
            let in_one_region = (WEIGHT_BASE..COL_BASE).contains(&a.addr)
                || (COL_BASE..ACT_A_BASE).contains(&a.addr)
                || (ACT_A_BASE..ACT_B_BASE).contains(&a.addr)
                || a.addr >= ACT_B_BASE;
            assert!(in_one_region, "stray address {:#x}", a.addr);
        }
    }

    #[test]
    fn batch_scales_trace_length() {
        let t1 = dnn_trace(&nets::alexnet(), 1).count();
        let t4 = dnn_trace(&nets::alexnet(), 4).count();
        assert!(t4 > t1 * 13 / 10, "batch-4 trace {t4} vs batch-1 {t1}");
    }

    #[test]
    fn col_buffer_is_rewritten_per_conv_layer() {
        // The shared column buffer address range recurs across layers.
        // Streaming keeps this VGG-scale walk allocation-free.
        let col_writes = dnn_trace(&nets::vgg16(), 1)
            .filter(|a| a.write && (COL_BASE..ACT_A_BASE).contains(&a.addr))
            .count();
        assert!(col_writes > 1_000_000, "vgg col traffic: {col_writes}");
    }

    #[test]
    fn streaming_is_deterministic_and_resumable() {
        // Two independent generators emit identical streams: the state
        // machine has no hidden global state.
        let net = nets::alexnet();
        let a = dnn_trace(&net, 1);
        let b = dnn_trace(&net, 1);
        let mut n = 0usize;
        for (x, y) in a.zip(b) {
            assert_eq!(x, y);
            n += 1;
        }
        assert!(n > 100_000);
    }

    #[test]
    fn run_queue_stays_bounded_per_layer() {
        // The streaming claim: queued work never approaches trace length.
        // SqueezeNet batch 4 has a ~4M-access trace; the generator's run
        // queue holds at most one layer's tiles (< 20k runs).
        let mut g = dnn_trace(&nets::squeezenet(), 4);
        let mut max_queued = 0usize;
        while g.next().is_some() {
            max_queued = max_queued.max(g.runs.len());
        }
        assert!(max_queued > 0 && max_queued < 20_000, "queue peak {max_queued}");
    }
}
