//! Address-trace generation from DNN layer descriptors.
//!
//! Replays the memory behaviour of the Caffe/DarkNet execution the paper
//! fed to GPGPU-Sim: per conv layer an im2col materialization into a
//! shared column buffer, then a tiled sgemm (64×64 threadblock tiles, the
//! cutlass-era shape) whose loop order re-reads the column buffer once per
//! N-tile and the weight tile once per M-sweep; activations ping-pong
//! between two buffers. Addresses are emitted at L2-line (128B)
//! granularity, post-L1 (each distinct line once per tile-level
//! operation — intra-tile reuse is register/SMEM-resident anyway).
//!
//! The reuse distances this produces are the whole point: AlexNet's
//! column buffers and conv weight tensors sit in the 1.5–18 MB range, so
//! sweeping the L2 from 3 MB to 24 MB progressively converts their
//! re-reads from DRAM traffic into L2 hits — Fig 7's mechanism.

use crate::workloads::dnn::{Dnn, Layer};
use crate::workloads::memstats::ELEM_BYTES;

/// Threadblock GEMM tile edge (M and N) in elements.
pub const TB_TILE: u64 = 128;

/// L2 line size the trace is quantized to (bytes).
pub const LINE: u64 = 128;

/// One memory access (line-aligned address).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Access {
    pub addr: u64,
    pub write: bool,
}

/// Address-space regions (disjoint by construction).
const WEIGHT_BASE: u64 = 0x1_0000_0000;
const COL_BASE: u64 = 0x8_0000_0000;
const ACT_A_BASE: u64 = 0x10_0000_0000;
const ACT_B_BASE: u64 = 0x18_0000_0000;

/// Trace builder.
pub struct TraceGen {
    out: Vec<Access>,
}

impl TraceGen {
    fn new() -> Self {
        TraceGen { out: Vec::new() }
    }

    /// Emit a sequential region touch, one access per line.
    fn region(&mut self, base: u64, bytes: u64, write: bool) {
        let lines = bytes.div_ceil(LINE);
        for l in 0..lines {
            self.out.push(Access {
                addr: base + l * LINE,
                write,
            });
        }
    }

    /// Emit the tiled GEMM access pattern: `out[M,N] = a[M,K] × b[K,N]`,
    /// with `a` at `a_base` (col buffer / activations) and `b` at `b_base`
    /// (weights). Loop order: M-tiles outer (output-stationary row sweep,
    /// the standard GPU sgemm schedule). Consequences for reuse distance:
    /// the A row-tile is re-read per N-tile at a *short* distance (one
    /// inner iteration), while each B (weight) column-tile is re-read once
    /// per M-tile at a distance of roughly `|B| + n_tiles·|A-tile|` —
    /// for AlexNet's conv3–conv5 that is 3.5–7 MB, which is exactly the
    /// window the paper's 3→24 MB capacity sweep opens (Fig 7).
    fn gemm(&mut self, m: u64, n: u64, k: u64, a_base: u64, b_base: u64, out_base: u64) {
        let m_tiles = m.div_ceil(TB_TILE);
        let n_tiles = n.div_ceil(TB_TILE);
        let a_tile_bytes = TB_TILE * k * ELEM_BYTES;
        let b_tile_bytes = k * TB_TILE * ELEM_BYTES;
        let out_tile_bytes = TB_TILE * TB_TILE * ELEM_BYTES;
        for mt in 0..m_tiles {
            // Edge tiles are clamped to the actual matrix extent.
            let tm = (m - mt * TB_TILE).min(TB_TILE);
            for nt in 0..n_tiles {
                let tn = (n - nt * TB_TILE).min(TB_TILE);
                // Read A row-tile (re-read once per N-tile, short distance).
                self.region(a_base + mt * a_tile_bytes, tm * k * ELEM_BYTES, false);
                // Read B column-tile (re-read per M-tile, medium distance).
                self.region(b_base + nt * b_tile_bytes, k * tn * ELEM_BYTES, false);
                // Write the output tile.
                self.region(
                    out_base + (mt * n_tiles + nt) * out_tile_bytes,
                    tm * tn * ELEM_BYTES,
                    true,
                );
            }
        }
    }
}

/// Generate the forward-pass trace of `net` at batch size `batch`.
pub fn dnn_trace(net: &Dnn, batch: u64) -> Vec<Access> {
    let mut g = TraceGen::new();
    let mut weight_off = 0u64;
    let mut input_is_a = true;
    for layer in &net.layers {
        let (in_base, out_base) = if input_is_a {
            (ACT_A_BASE, ACT_B_BASE)
        } else {
            (ACT_B_BASE, ACT_A_BASE)
        };
        let i_bytes = layer.input.numel() * batch * ELEM_BYTES;
        let o_bytes = layer.output.numel() * batch * ELEM_BYTES;
        let w_bytes = layer.weights() * ELEM_BYTES;
        match layer.layer {
            Layer::Conv { out_c, kernel, groups, .. } => {
                let m = batch * layer.output.h * layer.output.w;
                let n = out_c;
                let k = (layer.input.c / groups) * kernel * kernel;
                let (a_base, a_stream) = if kernel > 1 {
                    // im2col: read the input, write the column buffer.
                    g.region(in_base, i_bytes, false);
                    g.region(COL_BASE, m * k * ELEM_BYTES, true);
                    (COL_BASE, true)
                } else {
                    (in_base, false)
                };
                let _ = a_stream;
                g.gemm(m, n, k, a_base, WEIGHT_BASE + weight_off, out_base);
            }
            Layer::Fc { out, .. } => {
                let m = batch;
                let n = out;
                let k = layer.input.numel();
                g.gemm(m, n, k, in_base, WEIGHT_BASE + weight_off, out_base);
            }
            Layer::Pool { .. } | Layer::GlobalPool { .. } | Layer::Concat { .. } => {
                g.region(in_base, i_bytes, false);
                g.region(out_base, o_bytes, true);
            }
        }
        weight_off += w_bytes.div_ceil(LINE) * LINE;
        input_is_a = !input_is_a;
    }
    g.out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::nets;

    #[test]
    fn trace_is_nonempty_and_line_aligned() {
        let t = dnn_trace(&nets::alexnet(), 1);
        assert!(t.len() > 100_000);
        assert!(t.iter().all(|a| a.addr % LINE == 0));
    }

    #[test]
    fn trace_contains_reads_and_writes() {
        let t = dnn_trace(&nets::squeezenet(), 1);
        let writes = t.iter().filter(|a| a.write).count();
        assert!(writes > 0 && writes < t.len());
    }

    #[test]
    fn regions_do_not_collide() {
        // Weight traffic must never alias the activation or col regions.
        let t = dnn_trace(&nets::alexnet(), 1);
        for a in &t {
            let in_one_region = (WEIGHT_BASE..COL_BASE).contains(&a.addr)
                || (COL_BASE..ACT_A_BASE).contains(&a.addr)
                || (ACT_A_BASE..ACT_B_BASE).contains(&a.addr)
                || a.addr >= ACT_B_BASE;
            assert!(in_one_region, "stray address {:#x}", a.addr);
        }
    }

    #[test]
    fn batch_scales_trace_length() {
        let t1 = dnn_trace(&nets::alexnet(), 1).len();
        let t4 = dnn_trace(&nets::alexnet(), 4).len();
        assert!(t4 > t1 * 13 / 10, "batch-4 trace {t4} vs batch-1 {t1}");
    }

    #[test]
    fn col_buffer_is_rewritten_per_conv_layer() {
        // The shared column buffer address range recurs across layers.
        let t = dnn_trace(&nets::vgg16(), 1);
        let col_writes = t
            .iter()
            .filter(|a| a.write && (COL_BASE..ACT_A_BASE).contains(&a.addr))
            .count();
        assert!(col_writes > 1_000_000, "vgg col traffic: {col_writes}");
    }
}
