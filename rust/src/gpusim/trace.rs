//! Address-trace compilation from the workload IR — streamed.
//!
//! Replays the memory behaviour of the Caffe/DarkNet execution the paper
//! fed to GPGPU-Sim, as per-op lowering rules over [`NetIr`]: per conv op
//! an im2col materialization into a shared column buffer, then a tiled
//! sgemm (128×128 threadblock tiles) whose loop order re-reads the column
//! buffer once per N-tile and the weight tile once per M-sweep;
//! activations ping-pong between two buffers. The sequence-model ops
//! compile through the same GEMM emitter: attention lowers to the fused
//! QKV projection, per-head score/context GEMMs against scratch Q/K/V
//! slices, a softmax sweep, and the output projection; embeddings gather
//! table rows; norms/elementwise stream. Addresses are emitted at L2-line
//! (128B) granularity, post-L1.
//!
//! The reuse distances this produces are the whole point: AlexNet's
//! column buffers and conv weight tensors sit in the 1.5–18 MB range, so
//! sweeping the L2 from 3 MB to 24 MB progressively converts their
//! re-reads from DRAM traffic into L2 hits — Fig 7's mechanism. The five
//! Table 3 CNNs compile to byte-for-byte the seed's streams (pinned in
//! `tests/golden.rs`).
//!
//! Compilation is **streaming**: [`net_trace`] returns [`TraceGen`], a
//! resumable state machine implementing `Iterator<Item = Access>`. The
//! trace is never materialized — memory stays O(tiles of the current op)
//! for the queued region runs versus O(trace) for a materialized
//! `Vec<Access>`, and generation fuses with simulation in a single pass.

use std::collections::VecDeque;

use crate::workloads::ir::{NetIr, Op};
use crate::workloads::memstats::ELEM_BYTES;

/// Threadblock GEMM tile edge (M and N) in elements.
pub const TB_TILE: u64 = 128;

/// L2 line size the trace is quantized to (bytes).
pub const LINE: u64 = 128;

/// One memory access (line-aligned address).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Access {
    pub addr: u64,
    pub write: bool,
}

/// Address-space regions (disjoint by construction).
const WEIGHT_BASE: u64 = 0x1_0000_0000;
const COL_BASE: u64 = 0x8_0000_0000;
const ACT_A_BASE: u64 = 0x10_0000_0000;
const ACT_B_BASE: u64 = 0x18_0000_0000;

/// A queued sequential region touch, expanded lazily one line at a time.
#[derive(Debug, Clone, Copy)]
struct Run {
    base: u64,
    bytes: u64,
    write: bool,
}

/// Streaming trace compiler: a resumable state machine over the net's
/// ops. Each op expands to a bounded queue of `Run`s (one per im2col
/// region or GEMM tile operand); `next()` walks the current run one L2
/// line at a time.
pub struct TraceGen<'a> {
    net: &'a NetIr,
    batch: u64,
    /// Next op to expand into `runs`.
    next_op: usize,
    weight_off: u64,
    input_is_a: bool,
    runs: VecDeque<Run>,
    /// Current run: (run, total lines, next line index).
    cur: Option<(Run, u64, u64)>,
}

impl<'a> TraceGen<'a> {
    fn new(net: &'a NetIr, batch: u64) -> Self {
        TraceGen {
            net,
            batch,
            next_op: 0,
            weight_off: 0,
            input_is_a: true,
            runs: VecDeque::new(),
            cur: None,
        }
    }

    /// Queue a sequential region touch, one access per line.
    fn push_region(&mut self, base: u64, bytes: u64, write: bool) {
        self.runs.push_back(Run { base, bytes, write });
    }

    /// Queue the tiled GEMM access pattern: `out[M,N] = a[M,K] × b[K,N]`,
    /// with `a` at `a_base` (col buffer / activations) and `b` at `b_base`
    /// (weights, or an activation operand for attention). Loop order:
    /// M-tiles outer (output-stationary row sweep, the standard GPU sgemm
    /// schedule). Consequences for reuse distance: the A row-tile is
    /// re-read per N-tile at a *short* distance (one inner iteration),
    /// while each B column-tile is re-read once per M-tile at a distance
    /// of roughly `|B| + n_tiles·|A-tile|` — for AlexNet's conv3–conv5
    /// that is 3.5–7 MB, which is exactly the window the paper's 3→24 MB
    /// capacity sweep opens (Fig 7).
    fn push_gemm(&mut self, m: u64, n: u64, k: u64, a_base: u64, b_base: u64, out_base: u64) {
        let m_tiles = m.div_ceil(TB_TILE);
        let n_tiles = n.div_ceil(TB_TILE);
        let a_tile_bytes = TB_TILE * k * ELEM_BYTES;
        let b_tile_bytes = k * TB_TILE * ELEM_BYTES;
        let out_tile_bytes = TB_TILE * TB_TILE * ELEM_BYTES;
        for mt in 0..m_tiles {
            // Edge tiles are clamped to the actual matrix extent.
            let tm = (m - mt * TB_TILE).min(TB_TILE);
            for nt in 0..n_tiles {
                let tn = (n - nt * TB_TILE).min(TB_TILE);
                // Read A row-tile (re-read once per N-tile, short distance).
                self.push_region(a_base + mt * a_tile_bytes, tm * k * ELEM_BYTES, false);
                // Read B column-tile (re-read per M-tile, medium distance).
                self.push_region(b_base + nt * b_tile_bytes, k * tn * ELEM_BYTES, false);
                // Write the output tile.
                self.push_region(
                    out_base + (mt * n_tiles + nt) * out_tile_bytes,
                    tm * tn * ELEM_BYTES,
                    true,
                );
            }
        }
    }

    /// Expand the next op into the run queue (advances the op cursor,
    /// weight offset and activation ping-pong).
    fn enqueue_op(&mut self) {
        let net = self.net;
        let batch = self.batch;
        let op = &net.ops[self.next_op];
        self.next_op += 1;
        let (in_base, out_base) = if self.input_is_a {
            (ACT_A_BASE, ACT_B_BASE)
        } else {
            (ACT_B_BASE, ACT_A_BASE)
        };
        let i_bytes = op.input.numel() * batch * ELEM_BYTES;
        let o_bytes = op.output.numel() * batch * ELEM_BYTES;
        let w_bytes = op.weights() * ELEM_BYTES;
        let weight_base = WEIGHT_BASE + self.weight_off;
        match op.op {
            Op::Conv { kernel, .. } => {
                let (m, n, k) = op.gemm_dims(batch).expect("conv has gemm dims");
                let a_base = if kernel > 1 {
                    // im2col: read the input, write the column buffer.
                    self.push_region(in_base, i_bytes, false);
                    self.push_region(COL_BASE, m * k * ELEM_BYTES, true);
                    COL_BASE
                } else {
                    in_base
                };
                self.push_gemm(m, n, k, a_base, weight_base, out_base);
            }
            Op::Fc { .. } | Op::MatMul { .. } => {
                let (m, n, k) = op.gemm_dims(batch).expect("fc/matmul has gemm dims");
                self.push_gemm(m, n, k, in_base, weight_base, out_base);
            }
            Op::Attention { heads } => {
                // Scratch layout in the COL region: [Q | K | V | scores |
                // context], per-head slices addressed by chunk offsets.
                let d = op.input.c;
                let dh = d / heads;
                let seq = op.input.h * op.input.w;
                let t_bytes = batch * seq * d * ELEM_BYTES;
                let s_total = batch * heads * seq * seq * ELEM_BYTES;
                let q_base = COL_BASE;
                let k_base = COL_BASE + t_bytes;
                let v_base = COL_BASE + 2 * t_bytes;
                let s_base = COL_BASE + 3 * t_bytes;
                let c_base = s_base + s_total;
                // Fused QKV projection into scratch.
                self.push_gemm(batch * seq, 3 * d, d, in_base, weight_base, q_base);
                // Per-head scores: Q · Kᵀ.
                for bh in 0..batch * heads {
                    let chunk = bh * seq * dh * ELEM_BYTES;
                    self.push_gemm(
                        seq,
                        seq,
                        dh,
                        q_base + chunk,
                        k_base + chunk,
                        s_base + bh * seq * seq * ELEM_BYTES,
                    );
                }
                // Softmax sweep over the score tensor.
                self.push_region(s_base, s_total, false);
                self.push_region(s_base, s_total, true);
                // Per-head context: softmax(scores) · V.
                for bh in 0..batch * heads {
                    let chunk = bh * seq * dh * ELEM_BYTES;
                    self.push_gemm(
                        seq,
                        dh,
                        seq,
                        s_base + bh * seq * seq * ELEM_BYTES,
                        v_base + chunk,
                        c_base + chunk,
                    );
                }
                // Output projection (weights after the QKV block).
                self.push_gemm(
                    batch * seq,
                    d,
                    d,
                    c_base,
                    weight_base + 3 * d * d * ELEM_BYTES,
                    out_base,
                );
            }
            Op::Norm => {
                self.push_region(in_base, i_bytes, false);
                self.push_region(weight_base, w_bytes, false);
                self.push_region(out_base, o_bytes, true);
            }
            Op::Elementwise { inputs } => {
                for _ in 0..inputs {
                    self.push_region(in_base, i_bytes, false);
                }
                self.push_region(out_base, o_bytes, true);
            }
            Op::Embed { .. } => {
                // Index stream, then the gathered table rows (bounded by
                // the table), then the output tokens.
                self.push_region(in_base, i_bytes, false);
                self.push_region(weight_base, o_bytes.min(w_bytes), false);
                self.push_region(out_base, o_bytes, true);
            }
            Op::Pool { .. } | Op::GlobalPool | Op::Concat { .. } => {
                self.push_region(in_base, i_bytes, false);
                self.push_region(out_base, o_bytes, true);
            }
        }
        self.weight_off += w_bytes.div_ceil(LINE) * LINE;
        self.input_is_a = !self.input_is_a;
    }
}

impl Iterator for TraceGen<'_> {
    type Item = Access;

    fn next(&mut self) -> Option<Access> {
        loop {
            if let Some((run, lines, next)) = &mut self.cur {
                if *next < *lines {
                    let a = Access {
                        addr: run.base + *next * LINE,
                        write: run.write,
                    };
                    *next += 1;
                    return Some(a);
                }
                self.cur = None;
            }
            if let Some(run) = self.runs.pop_front() {
                self.cur = Some((run, run.bytes.div_ceil(LINE), 0));
                continue;
            }
            if self.next_op >= self.net.ops.len() {
                return None;
            }
            self.enqueue_op();
        }
    }
}

/// Stream the forward-pass trace of `net` at batch size `batch`.
pub fn net_trace(net: &NetIr, batch: u64) -> TraceGen<'_> {
    TraceGen::new(net, batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{nets, registry};

    #[test]
    fn trace_is_nonempty_and_line_aligned() {
        let t: Vec<Access> = net_trace(&nets::alexnet(), 1).collect();
        assert!(t.len() > 100_000);
        assert!(t.iter().all(|a| a.addr % LINE == 0));
    }

    #[test]
    fn trace_contains_reads_and_writes() {
        let (mut writes, mut total) = (0usize, 0usize);
        for a in net_trace(&nets::squeezenet(), 1) {
            total += 1;
            writes += a.write as usize;
        }
        assert!(writes > 0 && writes < total);
    }

    fn in_region(addr: u64) -> bool {
        (WEIGHT_BASE..COL_BASE).contains(&addr)
            || (COL_BASE..ACT_A_BASE).contains(&addr)
            || (ACT_A_BASE..ACT_B_BASE).contains(&addr)
            || addr >= ACT_B_BASE
    }

    #[test]
    fn regions_do_not_collide() {
        // Weight traffic must never alias the activation or col regions —
        // for the CNNs and for the attention scratch layout alike.
        for a in net_trace(&nets::alexnet(), 1) {
            assert!(in_region(a.addr), "stray address {:#x}", a.addr);
        }
        for net in [registry::gpt_block(), registry::lstm()] {
            for a in net_trace(&net, 2) {
                assert!(in_region(a.addr), "{}: stray address {:#x}", net.id, a.addr);
            }
        }
    }

    #[test]
    fn batch_scales_trace_length() {
        let t1 = net_trace(&nets::alexnet(), 1).count();
        let t4 = net_trace(&nets::alexnet(), 4).count();
        assert!(t4 > t1 * 13 / 10, "batch-4 trace {t4} vs batch-1 {t1}");
    }

    #[test]
    fn col_buffer_is_rewritten_per_conv_layer() {
        // The shared column buffer address range recurs across layers.
        // Streaming keeps this VGG-scale walk allocation-free.
        let col_writes = net_trace(&nets::vgg16(), 1)
            .filter(|a| a.write && (COL_BASE..ACT_A_BASE).contains(&a.addr))
            .count();
        assert!(col_writes > 1_000_000, "vgg col traffic: {col_writes}");
    }

    #[test]
    fn streaming_is_deterministic_and_resumable() {
        // Two independent generators emit identical streams: the state
        // machine has no hidden global state.
        let net = nets::alexnet();
        let a = net_trace(&net, 1);
        let b = net_trace(&net, 1);
        let mut n = 0usize;
        for (x, y) in a.zip(b) {
            assert_eq!(x, y);
            n += 1;
        }
        assert!(n > 100_000);
    }

    #[test]
    fn run_queue_stays_bounded_per_op() {
        // The streaming claim: queued work never approaches trace length —
        // including the attention fan-out, which queues per-head GEMMs.
        for (net, batch) in [(nets::squeezenet(), 4), (registry::vit_encoder(), 1)] {
            let mut g = net_trace(&net, batch);
            let mut max_queued = 0usize;
            while g.next().is_some() {
                max_queued = max_queued.max(g.runs.len());
            }
            assert!(
                max_queued > 0 && max_queued < 20_000,
                "{}: queue peak {max_queued}",
                net.id
            );
        }
    }

    #[test]
    fn attention_emits_scratch_and_weight_traffic() {
        let net = registry::gpt_block();
        let mut scratch_reads = 0usize;
        let mut weight_reads = 0usize;
        for a in net_trace(&net, 1) {
            if !a.write && (COL_BASE..ACT_A_BASE).contains(&a.addr) {
                scratch_reads += 1;
            }
            if !a.write && (WEIGHT_BASE..COL_BASE).contains(&a.addr) {
                weight_reads += 1;
            }
        }
        assert!(scratch_reads > 1000, "score/context scratch: {scratch_reads}");
        assert!(weight_reads > 100_000, "unembed weight streams: {weight_reads}");
    }

    #[test]
    fn lstm_trace_reflects_gate_gemms() {
        let n = net_trace(&registry::lstm(), 1).count();
        assert!(n > 100_000, "lstm trace {n}");
    }
}
