//! Policy-generic set-associative cache model — the L1/L2 building block
//! of the trace-driven simulator.
//!
//! The tag array is shared SoA state (`tags` + per-set `dirty` bitmask);
//! what varies is *policy*, split along the two axes that matter for NVM
//! caches:
//!
//! * [`ReplacementPolicy`] — victim selection. [`TrueLru`] is bit-identical
//!   to the original fused-scan implementation (pinned in
//!   `tests/golden.rs`); [`TreePlru`] and [`Srrip`] are the standard
//!   cheaper/scan-resistant alternatives.
//! * [`WritePolicy`] — write handling. NVM write energy dominates
//!   (DeepNVM++ charges read and write transactions separately), so how
//!   many writes actually touch the array is a first-order design knob:
//!   write-back/write-allocate (the default), write-through/no-allocate,
//!   and an NVM-aware *write-bypass* mode that streams write misses past
//!   the cache to DRAM while keeping write hits cached.
//!
//! Performance note (this is the simulator's hot path): each set is one
//! **packed record** in a single contiguous `u64` array — `assoc` tag
//! words, then the dirty bitmask word, then the replacement policy's
//! packed metadata words ([`ReplacementPolicy::meta_words`]). One access
//! therefore touches one short run of host cache lines (probe scan,
//! dirty update and metadata update all land in the same record) instead
//! of striding three parallel arrays, and the probe is still a
//! branch-light scan the compiler vectorizes. Policy dispatch is
//! monomorphized ([`PolicyCache`] is generic over the replacement
//! policy); the config-driven simulator selects the instantiation once
//! per run, not per access.

/// Access outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Hit,
    /// Miss; no dirty line was evicted (empty way, clean victim, or a
    /// no-allocate write miss that bypassed the cache).
    Miss,
    /// Miss that evicted a dirty line (costs a write-back).
    MissDirtyEvict,
}

use crate::reliability::FaultState;

/// Invalid-way sentinel in the tag array.
const EMPTY: u64 = u64::MAX;

/// Retired-way sentinel in the tag array: the way crossed its endurance
/// budget and holds no line. It matches no real tag (line addresses near
/// `u64::MAX` would need an address space of 2⁶⁴ lines) and is not
/// `EMPTY`, so the fused probe skips it without a dedicated branch — and
/// since a way only wears by being written, a retired slot was always
/// previously filled, keeping the EMPTY-suffix invariant intact.
const RETIRED: u64 = u64::MAX - 1;

/// How writes are handled (the NVM-critical axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum WritePolicy {
    /// Write-back / write-allocate: write misses fill the line, dirty
    /// lines write back on eviction (the seed behavior, and the default).
    #[default]
    WriteBack,
    /// Write-through / no-allocate: every write also goes to the next
    /// level; write misses do not allocate. Nothing is ever dirty.
    WriteThrough,
    /// Write-back for hits, no-allocate for write misses: streaming write
    /// misses go straight to DRAM instead of costing an NVM fill+write —
    /// the paper-motivated mode for write-asymmetric STT/SOT arrays.
    WriteBypass,
}

impl WritePolicy {
    /// All policies, in documentation order.
    pub const ALL: [WritePolicy; 3] =
        [WritePolicy::WriteBack, WritePolicy::WriteThrough, WritePolicy::WriteBypass];

    /// Short name used in CLI flags, `[space]`/`[cache]` sections and CSVs.
    pub fn name(&self) -> &'static str {
        match self {
            WritePolicy::WriteBack => "wb",
            WritePolicy::WriteThrough => "wt",
            WritePolicy::WriteBypass => "bypass",
        }
    }

    /// Parse a CLI/descriptor spelling.
    pub fn parse(s: &str) -> crate::Result<WritePolicy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "wb" | "writeback" | "write-back" => Ok(WritePolicy::WriteBack),
            "wt" | "writethrough" | "write-through" => Ok(WritePolicy::WriteThrough),
            "bypass" | "write-bypass" | "wb-nwa" => Ok(WritePolicy::WriteBypass),
            other => Err(crate::util::err::msg(format!(
                "unknown write policy {other:?} (known: wb, wt, bypass)"
            ))),
        }
    }
}

/// Replacement-policy selector — the data-side handle for the
/// [`ReplacementPolicy`] implementations, used wherever the policy is
/// configuration rather than a type parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Replacement {
    /// True LRU (per-way timestamps) — the seed behavior, and the default.
    #[default]
    Lru,
    /// Tree pseudo-LRU (one bit per tag-array node).
    TreePlru,
    /// Static RRIP (2-bit re-reference prediction, hit promotion).
    Srrip,
}

impl Replacement {
    /// All replacement policies, in documentation order.
    pub const ALL: [Replacement; 3] =
        [Replacement::Lru, Replacement::TreePlru, Replacement::Srrip];

    /// Short name used in CLI flags, `[space]`/`[cache]` sections and CSVs.
    pub fn name(&self) -> &'static str {
        match self {
            Replacement::Lru => "lru",
            Replacement::TreePlru => "plru",
            Replacement::Srrip => "srrip",
        }
    }

    /// Parse a CLI/descriptor spelling.
    pub fn parse(s: &str) -> crate::Result<Replacement> {
        match s.trim().to_ascii_lowercase().as_str() {
            "lru" => Ok(Replacement::Lru),
            "plru" | "tree-plru" | "treeplru" => Ok(Replacement::TreePlru),
            "srrip" | "rrip" => Ok(Replacement::Srrip),
            other => Err(crate::util::err::msg(format!(
                "unknown replacement policy {other:?} (known: lru, plru, srrip)"
            ))),
        }
    }
}

/// Victim selection over one set's **packed metadata slice** — the
/// `meta_words` words stored right after the set's tags and dirty word in
/// the cache's per-set record. All per-set state lives in that slice
/// (touching way `w` of set `s` reads/writes only set `s`'s record); the
/// policy object itself carries only *global scalar* state such as the
/// LRU tick. Set-locality is the invariant the set-sharded parallel
/// simulator rests on.
pub trait ReplacementPolicy {
    /// Global scalar state for an `assoc`-way cache.
    fn new(assoc: usize) -> Self;
    /// Packed metadata words needed per set for `assoc` ways.
    fn meta_words(assoc: usize) -> usize;
    /// Initialize one set's packed metadata (slice length is
    /// `meta_words(assoc)`).
    fn init_meta(meta: &mut [u64], assoc: usize);
    /// Promote `way` after a hit.
    fn touch(&mut self, meta: &mut [u64], way: usize);
    /// Install into `way` after a miss fill.
    fn fill(&mut self, meta: &mut [u64], way: usize);
    /// Pick the eviction way. Only called on a full set.
    fn victim(&mut self, meta: &mut [u64]) -> usize;
}

/// True LRU: one timestamp word per way in the set record, victim =
/// oldest. Equivalent to the seed's fused scan: the (global) tick
/// increments once per touch/fill, so the relative order of timestamps —
/// all victim selection uses — matches the original access-counter
/// scheme exactly.
#[derive(Debug, Clone)]
pub struct TrueLru {
    tick: u64,
}

impl ReplacementPolicy for TrueLru {
    fn new(_assoc: usize) -> TrueLru {
        TrueLru { tick: 0 }
    }

    fn meta_words(assoc: usize) -> usize {
        assoc
    }

    fn init_meta(meta: &mut [u64], _assoc: usize) {
        meta.fill(0);
    }

    #[inline]
    fn touch(&mut self, meta: &mut [u64], way: usize) {
        self.tick += 1;
        meta[way] = self.tick;
    }

    #[inline]
    fn fill(&mut self, meta: &mut [u64], way: usize) {
        self.touch(meta, way);
    }

    #[inline]
    fn victim(&mut self, meta: &mut [u64]) -> usize {
        let mut victim = 0usize;
        let mut victim_lru = u64::MAX;
        for (i, &l) in meta.iter().enumerate() {
            if l < victim_lru {
                victim_lru = l;
                victim = i;
            }
        }
        victim
    }
}

/// Tree pseudo-LRU: a binary tree of direction bits per set (packed into
/// the set record's single metadata word, so `assoc <= 64`). Touching a
/// way points every node on its root path away from it; the victim walk
/// follows the bits. Non-power-of-two associativities use the next
/// power-of-two tree with the out-of-range leaves statically skipped.
#[derive(Debug, Clone)]
pub struct TreePlru {
    assoc: usize,
    /// Leaf count: `assoc` rounded up to a power of two.
    leaves: usize,
}

impl TreePlru {
    /// Way index of the leftmost leaf under heap node `n`.
    #[inline]
    fn leftmost_way(mut n: usize, leaves: usize) -> usize {
        while n < leaves {
            n <<= 1;
        }
        n - leaves
    }
}

impl ReplacementPolicy for TreePlru {
    fn new(assoc: usize) -> TreePlru {
        assert!(assoc <= 64, "tree-PLRU packs at most 64 ways per set word");
        TreePlru { assoc, leaves: assoc.next_power_of_two() }
    }

    fn meta_words(_assoc: usize) -> usize {
        1
    }

    fn init_meta(meta: &mut [u64], _assoc: usize) {
        meta[0] = 0;
    }

    #[inline]
    fn touch(&mut self, meta: &mut [u64], way: usize) {
        // Direction-bit word: bit `n-1` = internal node `n`.
        let bits = &mut meta[0];
        let mut node = self.leaves + way;
        while node > 1 {
            let parent = node / 2;
            let bit = 1u64 << (parent - 1);
            if node & 1 == 0 {
                // `way` lives left of `parent`: point the victim walk right.
                *bits |= bit;
            } else {
                *bits &= !bit;
            }
            node = parent;
        }
    }

    #[inline]
    fn fill(&mut self, meta: &mut [u64], way: usize) {
        self.touch(meta, way);
    }

    #[inline]
    fn victim(&mut self, meta: &mut [u64]) -> usize {
        let bits = meta[0];
        let mut node = 1usize;
        while node < self.leaves {
            let b = ((bits >> (node - 1)) & 1) as usize;
            let mut next = 2 * node + b;
            // A subtree whose leftmost way is out of range holds no real
            // way at all (leaves are ordered): take the sibling.
            if Self::leftmost_way(next, self.leaves) >= self.assoc {
                next = 2 * node + (1 - b);
            }
            node = next;
        }
        node - self.leaves
    }
}

/// SRRIP re-reference ceiling (2-bit RRPV).
const RRPV_MAX: u8 = 3;

/// Read the 2-bit RRPV field for `way` from a set's packed metadata
/// (32 ways per word, little-endian field order).
#[inline]
fn rrpv_get(meta: &[u64], way: usize) -> u8 {
    ((meta[way / 32] >> (2 * (way % 32))) & 3) as u8
}

/// Write the 2-bit RRPV field for `way` in a set's packed metadata.
#[inline]
fn rrpv_set(meta: &mut [u64], way: usize, v: u8) {
    let (word, shift) = (way / 32, 2 * (way % 32));
    meta[word] = (meta[word] & !(3u64 << shift)) | (u64::from(v) << shift);
}

/// Static RRIP (SRRIP-HP): 2-bit re-reference prediction values per way,
/// packed 32 to a metadata word. Fills install at "long"
/// (`RRPV_MAX - 1`), hits promote to 0, the victim is the first way at
/// `RRPV_MAX` (aging the set until one exists) — scan-resistant where
/// LRU thrashes.
#[derive(Debug, Clone)]
pub struct Srrip {
    assoc: usize,
}

impl ReplacementPolicy for Srrip {
    fn new(assoc: usize) -> Srrip {
        Srrip { assoc }
    }

    fn meta_words(assoc: usize) -> usize {
        assoc.div_ceil(32)
    }

    fn init_meta(meta: &mut [u64], assoc: usize) {
        // Every real way starts at RRPV_MAX (0b11), exactly like the
        // unpacked `vec![RRPV_MAX; ..]`; padding fields past `assoc` stay
        // 0 and are never read (all loops run `0..assoc`).
        meta.fill(0);
        for way in 0..assoc {
            rrpv_set(meta, way, RRPV_MAX);
        }
    }

    #[inline]
    fn touch(&mut self, meta: &mut [u64], way: usize) {
        rrpv_set(meta, way, 0);
    }

    #[inline]
    fn fill(&mut self, meta: &mut [u64], way: usize) {
        rrpv_set(meta, way, RRPV_MAX - 1);
    }

    #[inline]
    fn victim(&mut self, meta: &mut [u64]) -> usize {
        loop {
            for way in 0..self.assoc {
                if rrpv_get(meta, way) == RRPV_MAX {
                    return way;
                }
            }
            // Age everyone (all fields are < RRPV_MAX here, so the +1
            // never carries out of a 2-bit field).
            for way in 0..self.assoc {
                let v = rrpv_get(meta, way);
                rrpv_set(meta, way, v + 1);
            }
        }
    }
}

/// Counter snapshot of one cache level (all in accesses/lines, not
/// transactions).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounters {
    pub hits: u64,
    pub misses: u64,
    /// Dirty evictions (write-back traffic to the next level).
    pub writebacks: u64,
    pub write_hits: u64,
    pub write_misses: u64,
    /// Writes that updated this array (hit updates + write-allocate
    /// installs) — what NVM write energy is charged on.
    pub array_writes: u64,
    /// Line fills from the next level (== misses under write-allocate).
    pub fills: u64,
    /// Writes forwarded directly to the next level (write-through
    /// traffic, and no-allocate write misses under through/bypass).
    pub direct_writes: u64,
}

/// A set-associative cache over a [`ReplacementPolicy`], with a
/// configurable [`WritePolicy`].
///
/// Perf (§Raw-speed pass in EXPERIMENTS.md): packed per-set records —
/// each set is `assoc` tag words (`EMPTY` = invalid), one dirty-bitmask
/// word (bit i = way i, so assoc ≤ 64), then the policy's packed
/// metadata words, contiguous in a single `u64` array. The tag probe is
/// still a branch-light scan the compiler vectorizes, and the dirty and
/// metadata updates that follow land in the same record the probe just
/// pulled into host cache.
#[derive(Debug, Clone)]
pub struct PolicyCache<P: ReplacementPolicy> {
    sets: usize,
    assoc: usize,
    line: u64,
    write: WritePolicy,
    /// Words per set record: `assoc` tags + 1 dirty word + policy meta.
    stride: usize,
    /// Packed per-set records, `sets × stride` words.
    data: Vec<u64>,
    policy: P,
    /// Fault injector (L2 under a `[rel]`-carrying technology only);
    /// `None` keeps every access on the exact fault-free path.
    faults: Option<FaultState>,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
    pub write_hits: u64,
    pub write_misses: u64,
    pub array_writes: u64,
    pub fills: u64,
    pub direct_writes: u64,
}

/// The default cache: true-LRU with a configurable write policy — the
/// seed's exact model under [`WritePolicy::WriteBack`].
pub type Cache = PolicyCache<TrueLru>;

impl<P: ReplacementPolicy> PolicyCache<P> {
    /// Build a write-back cache of `capacity` bytes with `line`-byte lines
    /// and `assoc` ways.
    pub fn new(capacity: u64, line: u64, assoc: u64) -> PolicyCache<P> {
        PolicyCache::with_write_policy(capacity, line, assoc, WritePolicy::WriteBack)
    }

    /// [`PolicyCache::new`] with an explicit write policy. Geometry must
    /// divide exactly: a capacity that silently truncated to fewer lines
    /// would simulate a smaller cache than asked for.
    pub fn with_write_policy(
        capacity: u64,
        line: u64,
        assoc: u64,
        write: WritePolicy,
    ) -> PolicyCache<P> {
        assert!(line > 0 && assoc > 0 && capacity > 0, "degenerate cache geometry");
        assert!(assoc <= 64, "dirty bitmask holds at most 64 ways");
        assert!(
            capacity % (line * assoc) == 0,
            "cache geometry: capacity {capacity} B is not a whole number of {assoc}-way sets \
             of {line} B lines (needs a multiple of {} B; {} B would be dropped)",
            line * assoc,
            capacity % (line * assoc)
        );
        let sets = ((capacity / line) / assoc) as usize;
        let assoc = assoc as usize;
        let stride = assoc + 1 + P::meta_words(assoc);
        let mut data = vec![0u64; sets * stride];
        for set in 0..sets {
            let base = set * stride;
            data[base..base + assoc].fill(EMPTY);
            // Dirty word (base + assoc) starts 0; policy meta follows.
            P::init_meta(&mut data[base + assoc + 1..base + stride], assoc);
        }
        PolicyCache {
            sets,
            assoc,
            line,
            write,
            stride,
            data,
            policy: P::new(assoc),
            faults: None,
            hits: 0,
            misses: 0,
            writebacks: 0,
            write_hits: 0,
            write_misses: 0,
            array_writes: 0,
            fills: 0,
            direct_writes: 0,
        }
    }

    #[inline]
    fn set_of(&self, addr: u64) -> (usize, u64) {
        let line_addr = addr / self.line;
        let set = (line_addr % self.sets as u64) as usize;
        (set, line_addr)
    }

    /// Access `addr`; returns the outcome and updates replacement/dirty
    /// state per the configured policies. With a fault injector attached,
    /// each physical array interaction additionally samples the fault
    /// model (reads: retention + disturb; writes/fills: write errors +
    /// wear) — without one, every fault branch is a predicted-false check
    /// on a `None` and the path is bit-identical to the fault-free build.
    #[inline]
    pub fn access(&mut self, addr: u64, is_write: bool) -> Outcome {
        let (set, tag) = self.set_of(addr);
        // The set's packed record: tags, then the dirty word, then the
        // policy metadata.
        let base = set * self.stride;
        let dirty_at = base + self.assoc;
        let meta_at = dirty_at + 1;
        let rec_end = base + self.stride;

        // A set whose every way has worn out caches nothing: the access
        // goes to DRAM. Writes are charged as direct (DRAM-bound) writes;
        // reads fetch without installing, so they count as fill-less
        // misses (degraded-mode accounting, documented in EXPERIMENTS.md).
        if let Some(f) = &self.faults {
            if f.all_retired(set) {
                self.misses += 1;
                if is_write {
                    self.write_misses += 1;
                    self.direct_writes += 1;
                }
                return Outcome::Miss;
            }
        }

        // One fused scan resolves both the hit probe and the fill way:
        // ways fill first-empty-first and tags never invalidate, so EMPTY
        // ways are a suffix — hitting one ends the probe (the tag cannot
        // sit past it) and names the fill way in the same pass. RETIRED
        // slots match neither arm and are skipped.
        let mut hit_way: Option<usize> = None;
        let mut empty_way: Option<usize> = None;
        for (i, &t) in self.data[base..base + self.assoc].iter().enumerate() {
            if t == tag {
                hit_way = Some(i);
                break;
            }
            if t == EMPTY {
                empty_way = Some(i);
                break;
            }
        }

        if let Some(way) = hit_way {
            {
                let (policy, data) = (&mut self.policy, &mut self.data);
                policy.touch(&mut data[meta_at..rec_end], way);
            }
            self.hits += 1;
            if is_write {
                self.write_hits += 1;
                self.array_writes += 1;
                match self.write {
                    WritePolicy::WriteBack | WritePolicy::WriteBypass => {
                        self.data[dirty_at] |= 1 << way;
                    }
                    WritePolicy::WriteThrough => self.direct_writes += 1,
                }
                let worn = match &mut self.faults {
                    Some(f) => f.sample_write(set, way),
                    None => false,
                };
                if worn {
                    self.retire_way(set, way);
                }
            } else if let Some(f) = &mut self.faults {
                f.sample_read(set);
            }
            return Outcome::Hit;
        }

        self.misses += 1;
        if is_write {
            self.write_misses += 1;
            if self.write != WritePolicy::WriteBack {
                // No-allocate: the write streams past this level (never
                // touching the array, so nothing to fault or wear).
                self.direct_writes += 1;
                return Outcome::Miss;
            }
        }

        // Allocate: first empty way, else the policy's victim (skipping
        // retired ways when a fault injector is live).
        self.fills += 1;
        let way = match empty_way {
            Some(w) => w,
            None => self.live_victim(set),
        };
        let dirty_evict = (self.data[dirty_at] >> way) & 1 == 1;
        if dirty_evict {
            self.writebacks += 1;
        }
        self.data[base + way] = tag;
        {
            let (policy, data) = (&mut self.policy, &mut self.data);
            policy.fill(&mut data[meta_at..rec_end], way);
        }
        if is_write {
            self.array_writes += 1;
            self.data[dirty_at] |= 1 << way;
        } else {
            self.data[dirty_at] &= !(1 << way);
        }
        // The fill itself is a physical array write: it faults and wears
        // like one (wear is therefore a superset of `array_writes`, which
        // charges demand writes only).
        let worn = match &mut self.faults {
            Some(f) => f.sample_write(set, way),
            None => false,
        };
        if worn {
            self.retire_way(set, way);
        }
        if dirty_evict {
            Outcome::MissDirtyEvict
        } else {
            Outcome::Miss
        }
    }

    /// The replacement policy's victim, excluding retired ways. Touching
    /// a retired way steers every policy's next choice elsewhere (LRU:
    /// newest timestamp; PLRU: root path flipped away; SRRIP: RRPV 0
    /// while live ways age), so the retry loop terminates; a bounded
    /// guard falls back to a linear scan regardless.
    #[inline]
    fn live_victim(&mut self, set: usize) -> usize {
        let meta_at = set * self.stride + self.assoc + 1;
        let rec_end = set * self.stride + self.stride;
        let no_retired = match &self.faults {
            None => true,
            Some(f) => f.retired_ways == 0,
        };
        if no_retired {
            let (policy, data) = (&mut self.policy, &mut self.data);
            return policy.victim(&mut data[meta_at..rec_end]);
        }
        for _ in 0..4 * self.assoc {
            let way = {
                let (policy, data) = (&mut self.policy, &mut self.data);
                policy.victim(&mut data[meta_at..rec_end])
            };
            let retired = self.faults.as_ref().is_some_and(|f| f.is_retired(set, way));
            if !retired {
                return way;
            }
            let (policy, data) = (&mut self.policy, &mut self.data);
            policy.touch(&mut data[meta_at..rec_end], way);
        }
        let f = self.faults.as_ref().expect("guarded above");
        (0..self.assoc)
            .find(|&w| !f.is_retired(set, w))
            .expect("fully-retired sets never allocate")
    }

    /// Retire `(set, way)` after its wear crossed the endurance budget:
    /// flush the line it holds (a dirty line costs a final write-back),
    /// mark the slot RETIRED, and shrink the set's live associativity.
    fn retire_way(&mut self, set: usize, way: usize) {
        let dirty_at = set * self.stride + self.assoc;
        if (self.data[dirty_at] >> way) & 1 == 1 {
            self.writebacks += 1;
            self.data[dirty_at] &= !(1 << way);
        }
        self.data[set * self.stride + way] = RETIRED;
        self.faults.as_mut().expect("retire without injector").retire(set, way);
    }

    /// Attach a fault injector (the simulator arms the L2 only). The
    /// injector must have been built for this cache's geometry.
    pub fn attach_faults(&mut self, faults: FaultState) {
        self.faults = Some(faults);
    }

    /// The attached fault injector's state, if any.
    pub fn faults(&self) -> Option<&FaultState> {
        self.faults.as_ref()
    }

    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn miss_rate(&self) -> f64 {
        self.misses as f64 / self.accesses().max(1) as f64
    }

    /// Counter snapshot (for merging sharded results).
    pub fn counters(&self) -> CacheCounters {
        CacheCounters {
            hits: self.hits,
            misses: self.misses,
            writebacks: self.writebacks,
            write_hits: self.write_hits,
            write_misses: self.write_misses,
            array_writes: self.array_writes,
            fills: self.fills,
            direct_writes: self.direct_writes,
        }
    }

    /// Reset counters (state retained) — the warmup/measure boundary of
    /// [`simulate`](super::sim::simulate)'s `--warmup-frac` mode.
    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.writebacks = 0;
        self.write_hits = 0;
        self.write_misses = 0;
        self.array_writes = 0;
        self.fills = 0;
        self.direct_writes = 0;
        // ECC outcome counters are measurement counters and reset with
        // the rest; wear and retirement are physical state and persist
        // (a warmup prefix ages the array exactly as real accesses do).
        if let Some(f) = &mut self.faults {
            f.corrected = 0;
            f.detected = 0;
            f.silent = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(1024, 64, 4);
        assert_eq!(c.access(0, false), Outcome::Miss);
        assert_eq!(c.access(0, false), Outcome::Hit);
        assert_eq!(c.access(63, false), Outcome::Hit, "same line");
        assert_eq!(c.access(64, false), Outcome::Miss, "next line");
    }

    #[test]
    fn lru_evicts_least_recent() {
        // 2-way, map everything to one set: 2 lines of 64B, sets=1.
        let mut c = Cache::new(128, 64, 2);
        c.access(0, false); // A
        c.access(64, false); // B
        c.access(0, false); // touch A
        c.access(128, false); // C evicts B (LRU)
        assert_eq!(c.access(0, false), Outcome::Hit, "A survived");
        assert_eq!(c.access(64, false), Outcome::Miss, "B evicted");
    }

    #[test]
    fn dirty_eviction_counts_writeback() {
        let mut c = Cache::new(128, 64, 2);
        c.access(0, true); // dirty A
        c.access(64, false); // B
        let out = c.access(128, false); // evicts dirty A
        assert_eq!(out, Outcome::MissDirtyEvict);
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn working_set_fitting_has_only_compulsory_misses() {
        let mut c = Cache::new(64 * 1024, 128, 16);
        for pass in 0..3 {
            for line in 0..256u64 {
                let out = c.access(line * 128, false);
                if pass > 0 {
                    assert_eq!(out, Outcome::Hit);
                }
            }
        }
        assert_eq!(c.misses, 256);
        assert_eq!(c.hits, 512);
    }

    #[test]
    fn streaming_larger_than_cache_always_misses() {
        let mut c = Cache::new(8 * 1024, 128, 4);
        for pass in 0..2 {
            let _ = pass;
            for line in 0..1024u64 {
                // 128KB stream through an 8KB cache.
                assert_ne!(c.access(line * 128, false), Outcome::Hit);
            }
        }
        assert_eq!(c.miss_rate(), 1.0);
    }

    #[test]
    fn counters_reset_keeps_contents() {
        let mut c = Cache::new(1024, 64, 4);
        c.access(0, true);
        c.reset_counters();
        assert_eq!(c.accesses(), 0);
        assert_eq!(c.counters(), CacheCounters::default());
        assert_eq!(c.access(0, false), Outcome::Hit, "state retained");
    }

    #[test]
    #[should_panic(expected = "degenerate")]
    fn zero_assoc_panics() {
        let _ = Cache::new(1024, 64, 0);
    }

    #[test]
    #[should_panic(expected = "960 B would be dropped")]
    fn truncating_capacity_is_rejected_loudly() {
        // 10000 B over 64B × 4-way sets: 10000 % 256 == 16... use numbers
        // whose remainder is stated in the assertion.
        let _ = Cache::new(4 * 1024 + 960, 64, 16);
    }

    #[test]
    fn plru_and_srrip_behave_like_caches() {
        // Basic cache identities hold for every replacement policy:
        // repeated access hits, a working set that fits stops missing.
        // 96KB divides into 6-way sets of 128B lines exactly (128 sets).
        let mut p: PolicyCache<TreePlru> = PolicyCache::new(96 * 1024, 128, 6);
        let mut s: PolicyCache<Srrip> = PolicyCache::new(64 * 1024, 128, 16);
        for pass in 0..2 {
            for line in 0..128u64 {
                let op = p.access(line * 128, false);
                let os = s.access(line * 128, false);
                if pass > 0 {
                    assert_eq!(op, Outcome::Hit, "plru line {line}");
                    assert_eq!(os, Outcome::Hit, "srrip line {line}");
                }
            }
        }
        assert_eq!(p.misses, 128);
        assert_eq!(s.misses, 128);
    }

    #[test]
    fn plru_single_set_evicts_an_untouched_way() {
        // 4 ways, one set. Fill A B C D, touch A and B again: the PLRU
        // victim must be C or D, never the freshly touched ways.
        let mut c: PolicyCache<TreePlru> = PolicyCache::new(4 * 64, 64, 4);
        for a in [0u64, 64, 128, 192] {
            c.access(a, false);
        }
        c.access(0, false);
        c.access(64, false);
        c.access(256, false); // evicts one of C/D
        assert_eq!(c.access(0, false), Outcome::Hit, "A protected");
        assert_eq!(c.access(64, false), Outcome::Hit, "B protected");
    }

    #[test]
    fn plru_non_pow2_assoc_stays_in_range() {
        // 6 ways (the Table 4 L1): the padded tree must never evict a
        // phantom way >= assoc. Exercise heavily under conflict.
        let mut c: PolicyCache<TreePlru> = PolicyCache::new(6 * 64, 64, 6);
        for i in 0..1000u64 {
            c.access((i % 13) * 64, i % 3 == 0);
        }
        assert_eq!(c.hits + c.misses, 1000);
    }

    #[test]
    fn srrip_resists_a_scan() {
        // A hot line re-referenced between one-shot scan lines survives
        // under SRRIP in a single set where LRU would keep churning.
        let mut c: PolicyCache<Srrip> = PolicyCache::new(4 * 64, 64, 4);
        c.access(0, false); // hot
        c.access(0, false); // promoted to RRPV 0
        for i in 1..64u64 {
            c.access(i * 64, false); // scan (install at long)
            assert_eq!(c.access(0, false), Outcome::Hit, "hot line evicted at scan {i}");
        }
    }

    #[test]
    fn write_through_never_writes_back() {
        let mut c: Cache = PolicyCache::with_write_policy(128, 64, 2, WritePolicy::WriteThrough);
        c.access(0, true); // write miss: no allocate, direct
        assert_eq!(c.access(0, false), Outcome::Miss, "write miss did not allocate");
        c.access(0, true); // write hit: array update + through
        c.access(64, false);
        c.access(128, false); // evicts — nothing dirty
        assert_eq!(c.writebacks, 0);
        assert_eq!(c.direct_writes, 2);
        assert_eq!(c.array_writes, 1, "only the write hit touched the array");
        assert_eq!(c.fills, 3, "read misses still fill");
    }

    #[test]
    fn write_bypass_keeps_write_hits_cached() {
        let mut c: Cache = PolicyCache::with_write_policy(128, 64, 2, WritePolicy::WriteBypass);
        c.access(0, false); // read fill
        c.access(0, true); // write hit: cached + dirty (no direct write)
        c.access(512, true); // write miss: bypassed
        assert_eq!(c.access(512, false), Outcome::Miss, "bypassed write did not allocate");
        assert_eq!(c.direct_writes, 1);
        assert_eq!(c.write_hits, 1);
        // The dirty hit line (LRU after 512 filled the other way) writes
        // back on eviction, like write-back.
        let out = c.access(64, false);
        assert_eq!(out, Outcome::MissDirtyEvict);
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn counter_identities_hold_per_policy() {
        for write in WritePolicy::ALL {
            let mut c: Cache = PolicyCache::with_write_policy(8 * 1024, 128, 4, write);
            let mut state = 9u64;
            for _ in 0..5000 {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                let addr = ((state >> 16) % 4096) * 128;
                let wr = state % 3 == 0;
                c.access(addr, wr);
            }
            assert_eq!(c.hits + c.misses, 5000, "{write:?}");
            assert!(c.write_hits <= c.hits && c.write_misses <= c.misses, "{write:?}");
            assert!(c.writebacks <= c.fills, "{write:?}");
            match write {
                WritePolicy::WriteBack => {
                    assert_eq!(c.direct_writes, 0);
                    assert_eq!(c.fills, c.misses);
                    assert_eq!(c.array_writes, c.write_hits + c.write_misses);
                }
                WritePolicy::WriteThrough => {
                    assert_eq!(c.writebacks, 0);
                    assert_eq!(c.direct_writes, c.write_hits + c.write_misses);
                    assert_eq!(c.array_writes, c.write_hits);
                    assert_eq!(c.fills, c.misses - c.write_misses);
                }
                WritePolicy::WriteBypass => {
                    assert_eq!(c.direct_writes, c.write_misses);
                    assert_eq!(c.array_writes, c.write_hits);
                    assert_eq!(c.fills, c.misses - c.write_misses);
                }
            }
        }
    }

    #[test]
    fn worn_ways_retire_and_the_set_degrades() {
        use crate::reliability::{FaultConfig, FaultState, RelSpec};
        // One 2-way set with a 3-cycle endurance budget; rates zeroed so
        // only wear mechanics act.
        let rel = RelSpec {
            endurance_cycles: 3.0,
            write_error_rate: 0.0,
            read_disturb_rate: 0.0,
            retention_tau: 1e12,
            ..RelSpec::stt_default()
        };
        let mut c = Cache::new(128, 64, 2);
        c.attach_faults(FaultState::new(&FaultConfig { rel, seed: 9 }, 1, 2, 512));
        c.access(0, false); // fill: wear 1
        c.access(0, true); // write hit: wear 2, dirty
        assert_eq!(c.writebacks, 0);
        c.access(0, true); // wear 3: crosses the budget — retire + flush
        assert_eq!(c.writebacks, 1, "retiring a dirty way writes it back");
        assert_eq!(c.faults().unwrap().retired_ways, 1);
        // The line is gone: re-access misses and fills the survivor.
        assert_eq!(c.access(0, false), Outcome::Miss);
        assert_eq!(c.access(0, false), Outcome::Hit);
        // Wear out the second way too (fill was 1, two write hits).
        c.access(0, true);
        c.access(0, true);
        assert!(c.faults().unwrap().all_retired(0));
        // The set is now uncacheable: everything misses, writes go
        // direct to DRAM, reads neither fill nor hit.
        let (fills, direct) = (c.fills, c.direct_writes);
        assert_eq!(c.access(0, false), Outcome::Miss);
        assert_eq!(c.access(0, true), Outcome::Miss);
        assert_eq!(c.fills, fills);
        assert_eq!(c.direct_writes, direct + 1);
        assert_eq!(c.faults().unwrap().max_wear(), 3);
    }

    #[test]
    fn victim_selection_skips_retired_ways_for_every_policy() {
        use crate::reliability::{FaultConfig, FaultState, RelSpec};
        fn churn<P: ReplacementPolicy>(name: &str) {
            let rel = RelSpec {
                endurance_cycles: 6.0,
                write_error_rate: 0.0,
                read_disturb_rate: 0.0,
                retention_tau: 1e12,
                ..RelSpec::stt_default()
            };
            // One 4-way set, 24 total write cycles before full wear-out.
            let mut c: PolicyCache<P> = PolicyCache::new(4 * 64, 64, 4);
            c.attach_faults(FaultState::new(&FaultConfig { rel, seed: 5 }, 1, 4, 512));
            for i in 0..200u64 {
                c.access((i % 8) * 64, true);
            }
            let f = c.faults().unwrap();
            assert!(f.all_retired(0), "{name}: 200 writes exhaust a 24-cycle set");
            assert_eq!(f.retired_ways, 4, "{name}");
            assert_eq!(f.max_wear(), 6, "{name}: no way wears past its budget");
            assert_eq!(c.hits + c.misses, 200, "{name}: accesses conserved");
        }
        churn::<TrueLru>("lru");
        churn::<TreePlru>("plru");
        churn::<Srrip>("srrip");
    }

    #[test]
    fn packed_meta_widths_match_policy_needs() {
        // The per-set record budget each policy declares: LRU needs a
        // timestamp word per way, PLRU one direction word, SRRIP packs
        // 32 2-bit fields per word.
        assert_eq!(<TrueLru as ReplacementPolicy>::meta_words(16), 16);
        assert_eq!(<TreePlru as ReplacementPolicy>::meta_words(16), 1);
        assert_eq!(<Srrip as ReplacementPolicy>::meta_words(16), 1);
        assert_eq!(<Srrip as ReplacementPolicy>::meta_words(32), 1);
        assert_eq!(<Srrip as ReplacementPolicy>::meta_words(33), 2);
        // Packed RRPV fields read back what was written, without
        // clobbering neighbors.
        let mut meta = [0u64; 2];
        Srrip::init_meta(&mut meta, 33);
        assert_eq!(rrpv_get(&meta, 0), RRPV_MAX);
        assert_eq!(rrpv_get(&meta, 32), RRPV_MAX);
        rrpv_set(&mut meta, 7, 1);
        assert_eq!(rrpv_get(&meta, 7), 1);
        assert_eq!(rrpv_get(&meta, 6), RRPV_MAX);
        assert_eq!(rrpv_get(&meta, 8), RRPV_MAX);
    }

    #[test]
    fn policy_names_parse_back() {
        for w in WritePolicy::ALL {
            assert_eq!(WritePolicy::parse(w.name()).unwrap(), w);
        }
        for r in Replacement::ALL {
            assert_eq!(Replacement::parse(r.name()).unwrap(), r);
        }
        assert_eq!(WritePolicy::parse("write-back").unwrap(), WritePolicy::WriteBack);
        assert_eq!(Replacement::parse("tree-plru").unwrap(), Replacement::TreePlru);
        assert!(WritePolicy::parse("wombat").is_err());
        assert!(Replacement::parse("fifo").is_err());
    }
}
