//! Set-associative cache model with true-LRU replacement and write-back /
//! write-allocate policy — the L1/L2 building block of the trace-driven
//! simulator.
//!
//! Performance note (this is the simulator's hot path): sets are flat
//! arrays of `(tag, lru_counter)` pairs; a lookup scans at most `assoc`
//! entries. With 16 ways that beats any pointer-chasing LRU list at these
//! sizes, and the layout is cache-friendly for the *host* CPU.

/// Access outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    Hit,
    /// Miss; evicted line was clean (or set had an empty way).
    Miss,
    /// Miss that evicted a dirty line (costs a write-back).
    MissDirtyEvict,
}

/// Invalid-way sentinel in the tag array.
const EMPTY: u64 = u64::MAX;

/// A set-associative write-back cache.
///
/// Perf (§Perf in EXPERIMENTS.md): structure-of-arrays layout — the tag
/// probe is a branch-light scan over a contiguous `u64` slice the
/// compiler vectorizes, with LRU counters and dirty bits in side arrays
/// touched only on their respective paths. ~25% faster trace replay than
/// the array-of-structs `(tag, lru, valid, dirty)` version.
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    assoc: usize,
    line: u64,
    /// Line tag per way (`EMPTY` = invalid), `sets × assoc`.
    tags: Vec<u64>,
    /// LRU timestamp per way.
    lru: Vec<u64>,
    /// Dirty bitmask per set (bit i = way i), assoc ≤ 64.
    dirty: Vec<u64>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl Cache {
    /// Build a cache of `capacity` bytes with `line`-byte lines and
    /// `assoc` ways. Capacity must divide evenly into sets.
    pub fn new(capacity: u64, line: u64, assoc: u64) -> Cache {
        let lines = capacity / line;
        assert!(lines >= assoc && assoc > 0, "degenerate cache geometry");
        assert!(assoc <= 64, "dirty bitmask holds at most 64 ways");
        let sets = (lines / assoc) as usize;
        Cache {
            sets,
            assoc: assoc as usize,
            line,
            tags: vec![EMPTY; sets * assoc as usize],
            lru: vec![0; sets * assoc as usize],
            dirty: vec![0; sets],
            tick: 0,
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    #[inline]
    fn set_of(&self, addr: u64) -> (usize, u64) {
        let line_addr = addr / self.line;
        let set = (line_addr % self.sets as u64) as usize;
        (set, line_addr)
    }

    /// Access `addr`; returns the outcome and updates LRU/dirty state.
    #[inline]
    pub fn access(&mut self, addr: u64, is_write: bool) -> Outcome {
        self.tick += 1;
        let (set, tag) = self.set_of(addr);
        let base = set * self.assoc;
        let tags = &mut self.tags[base..base + self.assoc];
        let lru = &mut self.lru[base..base + self.assoc];

        // Hit + victim in one fused scan over the SoA slices (branch-lean:
        // the victim bookkeeping is two compares on already-loaded words).
        let mut victim = 0usize;
        let mut victim_lru = u64::MAX;
        for (i, (&t, &l)) in tags.iter().zip(lru.iter()).enumerate() {
            if t == tag {
                lru[i] = self.tick;
                if is_write {
                    self.dirty[set] |= 1 << i;
                }
                self.hits += 1;
                return Outcome::Hit;
            }
            let key = if t == EMPTY { 0 } else { l };
            if key < victim_lru {
                victim_lru = key;
                victim = i;
            }
        }
        self.misses += 1;
        let was_valid = tags[victim] != EMPTY;
        let dirty_evict = was_valid && (self.dirty[set] >> victim) & 1 == 1;
        if dirty_evict {
            self.writebacks += 1;
        }
        tags[victim] = tag;
        lru[victim] = self.tick;
        if is_write {
            self.dirty[set] |= 1 << victim;
        } else {
            self.dirty[set] &= !(1 << victim);
        }
        if dirty_evict {
            Outcome::MissDirtyEvict
        } else {
            Outcome::Miss
        }
    }

    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    pub fn miss_rate(&self) -> f64 {
        self.misses as f64 / self.accesses().max(1) as f64
    }

    /// Reset counters (state retained) — used between warmup and measure.
    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.writebacks = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(1024, 64, 4);
        assert_eq!(c.access(0, false), Outcome::Miss);
        assert_eq!(c.access(0, false), Outcome::Hit);
        assert_eq!(c.access(63, false), Outcome::Hit, "same line");
        assert_eq!(c.access(64, false), Outcome::Miss, "next line");
    }

    #[test]
    fn lru_evicts_least_recent() {
        // 2-way, map everything to one set: 2 lines of 64B, sets=1.
        let mut c = Cache::new(128, 64, 2);
        c.access(0, false); // A
        c.access(64, false); // B
        c.access(0, false); // touch A
        c.access(128, false); // C evicts B (LRU)
        assert_eq!(c.access(0, false), Outcome::Hit, "A survived");
        assert_eq!(c.access(64, false), Outcome::Miss, "B evicted");
    }

    #[test]
    fn dirty_eviction_counts_writeback() {
        let mut c = Cache::new(128, 64, 2);
        c.access(0, true); // dirty A
        c.access(64, false); // B
        let out = c.access(128, false); // evicts dirty A
        assert_eq!(out, Outcome::MissDirtyEvict);
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn working_set_fitting_has_only_compulsory_misses() {
        let mut c = Cache::new(64 * 1024, 128, 16);
        for pass in 0..3 {
            for line in 0..256u64 {
                let out = c.access(line * 128, false);
                if pass > 0 {
                    assert_eq!(out, Outcome::Hit);
                }
            }
        }
        assert_eq!(c.misses, 256);
        assert_eq!(c.hits, 512);
    }

    #[test]
    fn streaming_larger_than_cache_always_misses() {
        let mut c = Cache::new(8 * 1024, 128, 4);
        for pass in 0..2 {
            let _ = pass;
            for line in 0..1024u64 {
                // 128KB stream through an 8KB cache.
                assert_ne!(c.access(line * 128, false), Outcome::Hit);
            }
        }
        assert_eq!(c.miss_rate(), 1.0);
    }

    #[test]
    fn counters_reset_keeps_contents() {
        let mut c = Cache::new(1024, 64, 4);
        c.access(0, true);
        c.reset_counters();
        assert_eq!(c.accesses(), 0);
        assert_eq!(c.access(0, false), Outcome::Hit, "state retained");
    }

    #[test]
    #[should_panic(expected = "degenerate")]
    fn zero_assoc_panics() {
        let _ = Cache::new(1024, 64, 0);
    }
}
