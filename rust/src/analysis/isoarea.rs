//! Iso-area analysis (paper §4.2 → Figs 8 and 9): the MRAM caches that
//! fit the SRAM baseline's footprint — STT-MRAM at 7MB, SOT-MRAM at 10MB
//! — evaluated with the capacity-dependent DRAM traffic the larger caches
//! enable (the Fig 7 effect). The same rule is available as a query via
//! [`Engine::fit_iso_area`]; the pinned capacities here are the paper's
//! regression-tested Table 2 values.

use crate::engine::{Engine, TECH_SOT, TECH_SRAM, TECH_STT};
use crate::util::units::MB;
use crate::workloads::profiler::paper_suite;
use super::model::{evaluate, Evaluation};

/// Iso-area capacities (regression-pinned to the paper's Table 2).
pub const ISO_AREA_STT: u64 = 7 * MB;
pub const ISO_AREA_SOT: u64 = 10 * MB;

/// Per-workload iso-area results normalized to SRAM (3MB).
#[derive(Debug, Clone)]
pub struct IsoAreaRow {
    pub label: String,
    /// `[STT, SOT]` normalized dynamic energy (Fig 8 top).
    pub dynamic: [f64; 2],
    /// `[STT, SOT]` normalized leakage energy (Fig 8 bottom).
    pub leakage: [f64; 2],
    /// `[STT, SOT]` normalized total cache energy.
    pub energy: [f64; 2],
    /// `[STT, SOT]` normalized EDP without DRAM (Fig 9 top).
    pub edp_cache: [f64; 2],
    /// `[STT, SOT]` normalized EDP with DRAM (Fig 9 bottom).
    pub edp_dram: [f64; 2],
    pub raw: [Evaluation; 3],
}

/// Run the iso-area analysis over the paper suite. Each technology's
/// workload statistics are profiled *at its own capacity* — the larger
/// MRAM caches absorb traffic that the 3MB SRAM sends to DRAM.
pub fn iso_area(engine: &Engine) -> Vec<IsoAreaRow> {
    let sram = engine.tuned(TECH_SRAM, 3 * MB).expect("builtin").ppa;
    let stt = engine.tuned(TECH_STT, ISO_AREA_STT).expect("builtin").ppa;
    let sot = engine.tuned(TECH_SOT, ISO_AREA_SOT).expect("builtin").ppa;
    paper_suite()
        .into_iter()
        .map(|w| {
            let p_sram =
                engine.profile_default(w.clone(), 3 * MB).expect("paper suite ids are builtin");
            let p_stt = engine
                .profile_default(w.clone(), ISO_AREA_STT)
                .expect("paper suite ids are builtin");
            let p_sot =
                engine.profile_default(w, ISO_AREA_SOT).expect("paper suite ids are builtin");
            let raw = [
                evaluate(&sram, &p_sram.stats),
                evaluate(&stt, &p_stt.stats),
                evaluate(&sot, &p_sot.stats),
            ];
            let norm =
                |f: &dyn Fn(&Evaluation) -> f64| [f(&raw[1]) / f(&raw[0]), f(&raw[2]) / f(&raw[0])];
            IsoAreaRow {
                label: p_sram.label,
                dynamic: norm(&|e| e.dynamic_energy),
                leakage: norm(&|e| e.leakage_energy),
                energy: norm(&|e| e.cache_energy()),
                edp_cache: norm(&|e| e.edp_cache()),
                edp_dram: norm(&|e| e.edp_with_dram()),
                raw,
            }
        })
        .collect()
}

/// Mean EDP reduction (with DRAM) per technology — the paper's 2.2× / 2.4×.
pub fn mean_edp_reduction(rows: &[IsoAreaRow]) -> [f64; 2] {
    let stt: Vec<f64> = rows.iter().map(|r| 1.0 / r.edp_dram[0]).collect();
    let sot: Vec<f64> = rows.iter().map(|r| 1.0 / r.edp_dram[1]).collect();
    [crate::util::stats::mean(&stt), crate::util::stats::mean(&sot)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    fn rows() -> Vec<IsoAreaRow> {
        iso_area(Engine::shared())
    }

    #[test]
    fn mean_edp_reduction_matches_paper_band() {
        // Paper: 2.2× (STT) and 2.4× (SOT) including DRAM; the abstract
        // quotes "up to" the same order.
        let rows = rows();
        let [stt, sot] = mean_edp_reduction(&rows);
        assert!((1.2..3.4).contains(&stt), "STT iso-area EDP reduction {stt}");
        assert!((1.7..3.8).contains(&sot), "SOT iso-area EDP reduction {sot}");
        assert!(sot > stt);
    }

    #[test]
    fn leakage_advantage_shrinks_vs_iso_capacity() {
        // Fig 8: at iso-area the bigger MRAM arrays leak more (2.2×/2.3×
        // advantage instead of 6.3×/10×).
        let rows = rows();
        let stt = mean(&rows.iter().map(|r| 1.0 / r.leakage[0]).collect::<Vec<_>>());
        let sot = mean(&rows.iter().map(|r| 1.0 / r.leakage[1]).collect::<Vec<_>>());
        assert!((1.4..3.6).contains(&stt), "STT leak advantage {stt}");
        assert!((1.5..4.2).contains(&sot), "SOT leak advantage {sot}");
    }

    #[test]
    fn larger_caches_cut_dram_traffic() {
        // The Fig 7 mechanism must show up in the raw evaluations.
        for row in rows() {
            assert!(
                row.raw[1].dram_energy <= row.raw[0].dram_energy,
                "{}: STT dram energy grew",
                row.label
            );
            assert!(row.raw[2].dram_energy <= row.raw[1].dram_energy);
        }
    }

    #[test]
    fn dynamic_energy_higher_at_iso_area_than_iso_capacity() {
        // Fig 8 vs Fig 4: bigger arrays cost more per access (2.5×/1.5×
        // vs 2.2×/1.3×).
        let ia = rows();
        let ic = crate::analysis::isocapacity::iso_capacity(Engine::shared());
        let m = |rows: &[f64]| mean(rows);
        let ia_stt = m(&ia.iter().map(|r| r.dynamic[0]).collect::<Vec<_>>());
        let ic_stt = m(&ic.iter().map(|r| r.dynamic[0]).collect::<Vec<_>>());
        assert!(ia_stt > ic_stt, "iso-area {ia_stt} vs iso-capacity {ic_stt}");
    }

    #[test]
    fn pinned_capacities_match_the_engine_fit() {
        // The Table 2 pins and the queryable iso-area rule must agree.
        let e = Engine::shared();
        assert_eq!(e.fit_iso_area("stt", 3 * MB).unwrap(), ISO_AREA_STT);
        assert_eq!(e.fit_iso_area("sot", 3 * MB).unwrap(), ISO_AREA_SOT);
    }
}
