//! Cross-layer analysis (paper §4): combines the NVSim-tuned cache PPA
//! with the profiled workload memory statistics into the paper's energy,
//! latency and EDP results.
//!
//! * [`model`] — the roll-up itself: serial transaction-latency time,
//!   leakage integration, DRAM bandwidth/energy model, cycle quantization.
//! * [`isocapacity`] — §4.1, Figs 4–5 (3MB, all technologies).
//! * [`batch`] — §4.1, Fig 6 (AlexNet batch-size sweep).
//! * [`isoarea`] — §4.2, Figs 8–9 (STT 7MB / SOT 10MB in the SRAM
//!   footprint, with capacity-dependent DRAM traffic).
//! * [`scalability`] — §4.3, Figs 10–13 (1–32MB, EDAP-tuned per point).

pub mod batch;
pub mod isoarea;
pub mod isocapacity;
pub mod model;
pub mod scalability;

pub use model::{evaluate, evaluate_with_dram, Evaluation};
