//! Iso-capacity analysis (paper §4.1 → Figs 4 and 5): all three
//! technologies at the GTX 1080 Ti's 3MB, driven by the profiled suite
//! through the query engine's memoized pipeline.

use crate::engine::{Engine, TECH_SOT, TECH_SRAM, TECH_STT};
use crate::util::units::MB;
use crate::workloads::profiler::PROFILE_L2;
use super::model::{evaluate, Evaluation};

/// Per-workload, per-technology iso-capacity results, all normalized to
/// the SRAM baseline (the paper's bar heights; <1 is better for MRAM).
#[derive(Debug, Clone)]
pub struct IsoCapacityRow {
    pub label: String,
    /// `[STT, SOT]` normalized dynamic energy (Fig 4 top).
    pub dynamic: [f64; 2],
    /// `[STT, SOT]` normalized leakage energy (Fig 4 bottom).
    pub leakage: [f64; 2],
    /// `[STT, SOT]` normalized total cache energy (Fig 5 top).
    pub energy: [f64; 2],
    /// `[STT, SOT]` normalized EDP incl. DRAM (Fig 5 bottom).
    pub edp: [f64; 2],
    /// Raw evaluations `[SRAM, STT, SOT]` for downstream consumers.
    pub raw: [Evaluation; 3],
}

/// Run the iso-capacity analysis over the full Fig 4 suite.
pub fn iso_capacity(engine: &Engine) -> Vec<IsoCapacityRow> {
    let caps = [
        engine.tuned(TECH_SRAM, 3 * MB).expect("builtin").ppa,
        engine.tuned(TECH_STT, 3 * MB).expect("builtin").ppa,
        engine.tuned(TECH_SOT, 3 * MB).expect("builtin").ppa,
    ];
    engine
        .profile_suite(PROFILE_L2)
        .into_iter()
        .map(|p| {
            let raw = [
                evaluate(&caps[0], &p.stats),
                evaluate(&caps[1], &p.stats),
                evaluate(&caps[2], &p.stats),
            ];
            let norm = |f: &dyn Fn(&Evaluation) -> f64| [f(&raw[1]) / f(&raw[0]), f(&raw[2]) / f(&raw[0])];
            IsoCapacityRow {
                label: p.label,
                dynamic: norm(&|e| e.dynamic_energy),
                leakage: norm(&|e| e.leakage_energy),
                energy: norm(&|e| e.cache_energy()),
                edp: norm(&|e| e.edp_with_dram()),
                raw,
            }
        })
        .collect()
}

/// Headline scalars from the iso-capacity run: the best (max) EDP
/// reduction factor per technology — the abstract's "up to 3.8× and 4.7×".
pub fn headline_edp_reduction(rows: &[IsoCapacityRow]) -> [f64; 2] {
    let mut best = [0.0f64; 2];
    for row in rows {
        for t in 0..2 {
            best[t] = best[t].max(1.0 / row.edp[t]);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::mean;

    fn rows() -> Vec<IsoCapacityRow> {
        iso_capacity(Engine::shared())
    }

    #[test]
    fn headline_edp_reductions_match_paper_band() {
        // Paper: up to 3.8× (STT) and 4.7× (SOT).
        let rows = rows();
        let [stt, sot] = headline_edp_reduction(&rows);
        assert!((2.8..5.2).contains(&stt), "STT max EDP reduction {stt}");
        assert!((3.5..7.5).contains(&sot), "SOT max EDP reduction {sot}");
        assert!(sot > stt, "SOT beats STT");
    }

    #[test]
    fn average_energy_reduction_matches_paper_band() {
        // Paper: 5.3× (STT) and 8.6× (SOT) mean cache-energy reduction.
        let rows = rows();
        let stt: Vec<f64> = rows.iter().map(|r| 1.0 / r.energy[0]).collect();
        let sot: Vec<f64> = rows.iter().map(|r| 1.0 / r.energy[1]).collect();
        let (ms, mo) = (mean(&stt), mean(&sot));
        assert!((3.8..7.0).contains(&ms), "STT mean energy reduction {ms}");
        assert!((6.2..11.0).contains(&mo), "SOT mean energy reduction {mo}");
    }

    #[test]
    fn stt_dynamic_energy_is_worse_sot_mildly_worse() {
        // Fig 4 top: STT ≈2.2×, SOT ≈1.3× SRAM.
        let rows = rows();
        let stt = mean(&rows.iter().map(|r| r.dynamic[0]).collect::<Vec<_>>());
        let sot = mean(&rows.iter().map(|r| r.dynamic[1]).collect::<Vec<_>>());
        assert!(stt > 1.4 && stt < 3.0, "STT dyn {stt}");
        assert!(sot > 1.0 && sot < 1.9, "SOT dyn {sot}");
    }

    #[test]
    fn every_workload_sees_mram_energy_win() {
        for row in rows() {
            assert!(row.energy[0] < 1.0, "{}: STT energy {}", row.label, row.energy[0]);
            assert!(row.energy[1] < 1.0, "{}: SOT energy {}", row.label, row.energy[1]);
        }
    }

    #[test]
    fn suite_rows_match_profiler_labels() {
        let rows = rows();
        assert_eq!(rows.len(), 13);
        assert_eq!(rows[0].label, "AlexNet-I");
    }
}
