//! Batch-size study (paper §4.1, Fig 6): AlexNet training and inference
//! EDP (normalized to SRAM) as the batch size sweeps. The batch grid is a
//! parameter since the query-engine redesign (`repro experiment fig6
//! --batches 1,8,128`); [`BATCHES`] is the paper's grid.

use crate::engine::{Engine, TECH_SOT, TECH_SRAM, TECH_STT};
use crate::util::units::MB;
use crate::workloads::memstats::Phase;
use crate::workloads::profiler::{Workload, PROFILE_L2};
use super::model::evaluate;

/// Batch sizes swept in Fig 6.
pub const BATCHES: [u64; 7] = [1, 2, 4, 8, 16, 32, 64];

/// One Fig 6 point: normalized EDP (with DRAM) for `[STT, SOT]` at a batch.
#[derive(Debug, Clone, Copy)]
pub struct BatchPoint {
    pub batch: u64,
    pub edp_norm: [f64; 2],
}

/// Sweep one phase of AlexNet over the given batch sizes.
pub fn batch_sweep(engine: &Engine, phase: Phase, batches: &[u64]) -> Vec<BatchPoint> {
    let caps = [
        engine.tuned(TECH_SRAM, 3 * MB).expect("builtin").ppa,
        engine.tuned(TECH_STT, 3 * MB).expect("builtin").ppa,
        engine.tuned(TECH_SOT, 3 * MB).expect("builtin").ppa,
    ];
    let alexnet = Workload::net("alexnet", phase);
    batches
        .iter()
        .map(|&batch| {
            let stats = engine
                .profile(alexnet.clone(), batch, PROFILE_L2)
                .expect("alexnet is builtin")
                .stats;
            let e: Vec<f64> = caps
                .iter()
                .map(|c| evaluate(c, &stats).edp_with_dram())
                .collect();
            BatchPoint {
                batch,
                edp_norm: [e[1] / e[0], e[2] / e[0]],
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sweep(phase: Phase) -> Vec<BatchPoint> {
        batch_sweep(Engine::shared(), phase, &BATCHES)
    }

    #[test]
    fn training_stt_improves_with_batch() {
        // Fig 6 top: STT 2.3×→4.6× EDP reduction as batch grows.
        let sweep = sweep(Phase::Training);
        let first = 1.0 / sweep.first().unwrap().edp_norm[0];
        let last = 1.0 / sweep.last().unwrap().edp_norm[0];
        assert!(
            last > first * 1.3,
            "STT training reduction must grow: {first} -> {last}"
        );
    }

    #[test]
    fn training_sot_is_flat_and_high() {
        // Fig 6 top: SOT ~7.2×–7.6× across batch sizes (variation small
        // relative to its level).
        let sweep = sweep(Phase::Training);
        let reds: Vec<f64> = sweep.iter().map(|p| 1.0 / p.edp_norm[1]).collect();
        let min = reds.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = reds.iter().cloned().fold(0.0, f64::max);
        assert!(min > 2.5, "SOT training reduction floor {min}");
        assert!(max / min < 2.0, "SOT training spread {min}..{max}");
    }

    #[test]
    fn inference_reductions_stay_in_band() {
        // Fig 6 bottom: STT 4.1–5.4×, SOT 7.1–7.3× — both phases see
        // substantial, relatively stable reductions.
        let sweep = sweep(Phase::Inference);
        for p in &sweep {
            let stt = 1.0 / p.edp_norm[0];
            let sot = 1.0 / p.edp_norm[1];
            assert!(stt > 1.5, "batch {}: STT {stt}", p.batch);
            assert!(sot > stt, "batch {}: SOT {sot} <= STT {stt}", p.batch);
        }
    }

    #[test]
    fn sweep_covers_all_batches_in_order() {
        let sweep = sweep(Phase::Inference);
        let batches: Vec<u64> = sweep.iter().map(|p| p.batch).collect();
        assert_eq!(batches, BATCHES.to_vec());
    }

    #[test]
    fn custom_batch_grid_is_respected() {
        let sweep = batch_sweep(Engine::shared(), Phase::Inference, &[2, 128]);
        assert_eq!(sweep.len(), 2);
        assert_eq!(sweep[1].batch, 128);
        assert!(sweep[1].edp_norm[0] > 0.0);
    }
}
