//! Scalability analysis (paper §4.3 → Figs 10–13): every technology
//! EDAP-tuned independently at each capacity of the grid, then the
//! workload suite evaluated on each design. The capacity grid is a
//! parameter since the query-engine redesign (`repro experiment fig10
//! --capacities 1,2,4`); [`CAPACITIES_MB`] is the paper's 1–32MB grid.

use crate::engine::{Engine, TECH_SOT, TECH_SRAM, TECH_STT};
use crate::nvsim::cache::CachePpa;
use crate::util::pool::par_map;
use crate::util::stats::{mean, stddev};
use crate::util::units::MB;
use crate::workloads::memstats::Phase;
use crate::workloads::profiler::{paper_suite, Workload};
use super::model::evaluate;

/// The capacity grid of Algorithm 1 / Fig 10 (MB).
pub const CAPACITIES_MB: [u64; 6] = [1, 2, 4, 8, 16, 32];

/// Fig 10: the tuned PPA of each technology at each capacity.
#[derive(Debug, Clone)]
pub struct PpaCurvePoint {
    pub capacity_mb: u64,
    /// `[SRAM, STT, SOT]`.
    pub ppa: [CachePpa; 3],
}

/// Compute the Fig 10 PPA-vs-capacity curves over `capacities_mb`
/// (tuning runs in parallel through the engine's memo cache).
pub fn ppa_curves(engine: &Engine, capacities_mb: &[u64]) -> Vec<PpaCurvePoint> {
    par_map(capacities_mb, |&mb| PpaCurvePoint {
        capacity_mb: mb,
        ppa: [
            engine.tuned(TECH_SRAM, mb * MB).expect("builtin").ppa,
            engine.tuned(TECH_STT, mb * MB).expect("builtin").ppa,
            engine.tuned(TECH_SOT, mb * MB).expect("builtin").ppa,
        ],
    })
}

/// Figs 11–13: normalized mean ± stddev across workloads of one phase.
#[derive(Debug, Clone)]
pub struct ScalingPoint {
    pub capacity_mb: u64,
    /// `[STT, SOT]` mean normalized energy across workloads.
    pub energy_mean: [f64; 2],
    pub energy_std: [f64; 2],
    /// `[STT, SOT]` mean normalized latency.
    pub latency_mean: [f64; 2],
    pub latency_std: [f64; 2],
    /// `[STT, SOT]` mean normalized EDP.
    pub edp_mean: [f64; 2],
    pub edp_std: [f64; 2],
}

fn phase_workloads(phase: Phase) -> Vec<Workload> {
    paper_suite()
        .into_iter()
        .filter(|w| match w {
            Workload::Net { phase: p, .. } => *p == phase,
            // HPCG joins the inference chart (single-phase workload).
            Workload::Hpcg(_) => phase == Phase::Inference,
        })
        .collect()
}

/// Scaling study for one phase: at each capacity of the grid, tune all
/// three technologies and evaluate the phase's workloads.
pub fn scaling_study(engine: &Engine, phase: Phase, capacities_mb: &[u64]) -> Vec<ScalingPoint> {
    let workloads = phase_workloads(phase);
    par_map(capacities_mb, |&mb| {
        let caps = [
            engine.tuned(TECH_SRAM, mb * MB).expect("builtin").ppa,
            engine.tuned(TECH_STT, mb * MB).expect("builtin").ppa,
            engine.tuned(TECH_SOT, mb * MB).expect("builtin").ppa,
        ];
        let mut energy = [Vec::new(), Vec::new()];
        let mut latency = [Vec::new(), Vec::new()];
        let mut edp = [Vec::new(), Vec::new()];
        for w in &workloads {
            let stats = engine
                .profile_default(w.clone(), mb * MB)
                .expect("paper suite ids are builtin")
                .stats;
            let evals: Vec<_> = caps.iter().map(|c| evaluate(c, &stats)).collect();
            for t in 0..2 {
                energy[t].push(evals[t + 1].total_energy() / evals[0].total_energy());
                latency[t].push(evals[t + 1].total_time() / evals[0].total_time());
                edp[t].push(evals[t + 1].edp_with_dram() / evals[0].edp_with_dram());
            }
        }
        ScalingPoint {
            capacity_mb: mb,
            energy_mean: [mean(&energy[0]), mean(&energy[1])],
            energy_std: [stddev(&energy[0]), stddev(&energy[1])],
            latency_mean: [mean(&latency[0]), mean(&latency[1])],
            latency_std: [stddev(&latency[0]), stddev(&latency[1])],
            edp_mean: [mean(&edp[0]), mean(&edp[1])],
            edp_std: [stddev(&edp[0]), stddev(&edp[1])],
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::units::{MM2, NS};

    fn curves() -> Vec<PpaCurvePoint> {
        ppa_curves(Engine::shared(), &CAPACITIES_MB)
    }

    fn study(phase: Phase) -> Vec<ScalingPoint> {
        scaling_study(Engine::shared(), phase, &CAPACITIES_MB)
    }

    #[test]
    fn fig10_area_gap_widens_with_capacity() {
        let curves = curves();
        let ratio = |p: &PpaCurvePoint, t: usize| p.ppa[0].area / p.ppa[t].area;
        let first = &curves[0];
        let last = curves.last().unwrap();
        for t in 1..3 {
            assert!(
                ratio(last, t) > ratio(first, t) * 0.9,
                "area advantage should persist/widen (tech {t})"
            );
            assert!(ratio(last, t) > 1.8, "MRAM clearly denser at 32MB");
        }
        // Absolute sanity: SRAM 32MB is tens of mm².
        assert!(last.ppa[0].area / MM2 > 30.0);
    }

    #[test]
    fn fig10_latency_crossover_exists() {
        // Paper: SRAM reads faster below ~3MB; MRAM wins beyond ~4MB.
        let curves = curves();
        let small = &curves[0]; // 1MB
        let large = curves.last().unwrap(); // 32MB
        assert!(
            small.ppa[0].read_latency < small.ppa[1].read_latency,
            "1MB: SRAM read faster"
        );
        assert!(
            large.ppa[0].read_latency > large.ppa[1].read_latency,
            "32MB: STT read faster ({} vs {} ns)",
            large.ppa[0].read_latency / NS,
            large.ppa[1].read_latency / NS
        );
    }

    #[test]
    fn fig10_stt_write_latency_always_worst() {
        for p in curves() {
            assert!(p.ppa[1].write_latency > p.ppa[0].write_latency);
            assert!(p.ppa[1].write_latency > p.ppa[2].write_latency);
        }
    }

    #[test]
    fn fig13_edp_reductions_grow_to_orders_of_magnitude() {
        // Paper: up to 65× (STT) and 95× (SOT) at large capacities.
        let pts = study(Phase::Inference);
        let last = pts.last().unwrap();
        let stt = 1.0 / last.edp_mean[0];
        let sot = 1.0 / last.edp_mean[1];
        assert!(stt > 7.0, "STT 32MB EDP reduction {stt}");
        assert!(sot > 25.0, "SOT 32MB EDP reduction {sot}");
        assert!(sot > stt);
        // And the trend is monotone-ish: 32MB beats 1MB by a lot.
        let first_stt = 1.0 / pts[0].edp_mean[0];
        assert!(stt > 4.0 * first_stt);
    }

    #[test]
    fn fig11_energy_reduction_grows_with_capacity() {
        // Paper: up to 31.2× / 36.4× energy reduction.
        for phase in [Phase::Inference, Phase::Training] {
            let pts = study(phase);
            let first = 1.0 / pts[0].energy_mean[1];
            let last = 1.0 / pts.last().unwrap().energy_mean[1];
            assert!(last > first, "{phase:?}: SOT energy advantage must grow");
            assert!(last > 10.0, "{phase:?}: SOT 32MB energy reduction {last}");
        }
    }

    #[test]
    fn error_bars_are_finite_and_nonnegative() {
        let pts = study(Phase::Training);
        for p in &pts {
            for t in 0..2 {
                assert!(p.energy_std[t] >= 0.0 && p.energy_std[t].is_finite());
                assert!(p.edp_std[t] >= 0.0 && p.edp_std[t].is_finite());
            }
        }
    }

    #[test]
    fn custom_capacity_grid_is_respected() {
        let pts = ppa_curves(Engine::shared(), &[2, 8]);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[1].capacity_mb, 8);
    }
}
