//! The cross-layer energy/latency roll-up (paper §4).
//!
//! Follows the paper's stated methodology exactly: "we used a simple model
//! where we multiply the number of read and write transactions by the
//! corresponding latency and energy values for those operations" — i.e.
//! the workload's cache time is the *serial* sum of its transactions at
//! the technology's (cycle-quantized) latencies, leakage energy is the
//! leakage power integrated over that time, and the DRAM contribution
//! (included in the EDP figures) adds a bandwidth-model delay and a
//! per-transaction energy.

use crate::gpusim::SimResult;
use crate::membackend::{DramConfig, DramStats};
use crate::nvsim::cache::CachePpa;
use crate::reliability::{RelEval, RelSpec, SECONDS_PER_YEAR};
use crate::workloads::memstats::{MemStats, TRANS_BYTES as SECTOR_BYTES};

/// GPU L2 clock (Table 4) — latencies are quantized to whole cycles
/// ("we convert read and write latencies to clock cycles based on 1080
/// Ti GPU's clock frequency").
pub const L2_CLOCK_HZ: f64 = 1481.0e6;

/// Effective DRAM bandwidth of the GTX 1080 Ti (GDDR5X, 484 GB/s).
pub const DRAM_BW: f64 = 484.0e9;

/// DRAM energy per 32-byte transaction (J): ~15 pJ/bit at the device plus
/// I/O — the "DRAM access is 200× a MAC" regime the paper cites.
pub const DRAM_E_PER_TRANS: f64 = 4.0e-9;

/// Bytes per transaction (nvprof sector).
pub const TRANS_BYTES: f64 = 32.0;

/// Quantize a latency up to whole L2 cycles.
pub fn to_cycles_latency(lat: f64) -> f64 {
    let cycle = 1.0 / L2_CLOCK_HZ;
    (lat / cycle).ceil() * cycle
}

/// Energy/latency evaluation of one workload on one cache design.
#[derive(Debug, Clone, Copy)]
pub struct Evaluation {
    /// Dynamic (read + write) cache energy (J).
    pub dynamic_energy: f64,
    /// Leakage energy over the workload's cache time (J).
    pub leakage_energy: f64,
    /// DRAM energy (J).
    pub dram_energy: f64,
    /// Serial cache time (s).
    pub cache_time: f64,
    /// DRAM transfer time (s).
    pub dram_time: f64,
}

impl Evaluation {
    /// Cache-only energy (the paper's Fig 4/5-top quantity).
    pub fn cache_energy(&self) -> f64 {
        self.dynamic_energy + self.leakage_energy
    }

    /// Total energy including DRAM.
    pub fn total_energy(&self) -> f64 {
        self.cache_energy() + self.dram_energy
    }

    /// Total delay including DRAM.
    pub fn total_time(&self) -> f64 {
        self.cache_time + self.dram_time
    }

    /// EDP without the DRAM contribution (Fig 9-top).
    pub fn edp_cache(&self) -> f64 {
        self.cache_energy() * self.cache_time
    }

    /// EDP with DRAM energy and latency (Fig 5-bottom, Fig 9-bottom).
    pub fn edp_with_dram(&self) -> f64 {
        self.total_energy() * self.total_time()
    }
}

/// Convert trace-simulation counters into the nvprof-equivalent 32-byte
/// transaction counters the roll-up consumes. This is where write policy
/// changes the DRAM- vs cache-write accounting:
///
/// * `l2_writes` charges only **array** writes (`l2_array_writes`) — under
///   write-back that is every write; under write-through/bypass the
///   no-allocate write misses never touch the (NVM) array and so cost no
///   cache write energy.
/// * `dram_writes` carries the flip side: write-back evictions *plus* the
///   through/bypassed write traffic (`SimResult::dram_writes`).
/// * `dram_reads` are the line fills, which shrink under no-allocate
///   policies (write misses stop fetching lines they only overwrite).
///
/// `line_bytes` is the simulated line size (one line access = `line /
/// 32` nvprof sectors).
pub fn stats_from_sim(sim: &SimResult, line_bytes: u64) -> MemStats {
    let t = (line_bytes / SECTOR_BYTES).max(1);
    let writes = sim.l2_write_hits + sim.l2_write_misses;
    let reads = sim.l2_accesses - writes;
    MemStats {
        l2_reads: reads * t,
        l2_writes: sim.l2_array_writes * t,
        dram_reads: sim.dram_fills * t,
        dram_writes: sim.dram_writes * t,
    }
}

/// Roll fault-campaign counters up into the reliability figures of merit.
///
/// * **UBER** — uncorrectable (silent) bit errors per bit read: the line
///   delivers `line_bits` bits per access, so the denominator is
///   `l2_accesses × line_bits` (0 accesses → 0.0, not NaN).
/// * **Lifetime** — the most-worn line absorbed `max_line_writes`
///   physical writes over the workload's `total_time_s`; running that
///   write rate against the endurance budget gives the array lifetime,
///   reported in years ([`f64::INFINITY`] when the campaign wrote
///   nothing — an idle array never wears out).
pub fn rel_from_sim(
    rel: &RelSpec,
    sim: &SimResult,
    line_bits: u64,
    total_time_s: f64,
) -> RelEval {
    let bits_read = (sim.l2_accesses * line_bits) as f64;
    let uber = if bits_read > 0.0 { sim.faults_silent as f64 / bits_read } else { 0.0 };
    let lifetime_years = if sim.max_line_writes == 0 {
        f64::INFINITY
    } else {
        rel.endurance_cycles / sim.max_line_writes as f64 * total_time_s / SECONDS_PER_YEAR
    };
    RelEval {
        uber,
        lifetime_years,
        corrected: sim.faults_corrected,
        detected: sim.faults_detected,
        silent: sim.faults_silent,
        retired_ways: sim.retired_ways,
    }
}

/// Evaluate `stats` on a cache with PPA `ppa`.
pub fn evaluate(ppa: &CachePpa, stats: &MemStats) -> Evaluation {
    let rl = to_cycles_latency(ppa.read_latency);
    let wl = to_cycles_latency(ppa.write_latency);
    let dynamic_energy =
        stats.l2_reads as f64 * ppa.read_energy + stats.l2_writes as f64 * ppa.write_energy;
    let cache_time = stats.l2_reads as f64 * rl + stats.l2_writes as f64 * wl;
    let leakage_energy = ppa.leakage_power * cache_time;
    let dram_trans = (stats.dram_reads + stats.dram_writes) as f64;
    let dram_energy = dram_trans * DRAM_E_PER_TRANS;
    let dram_time = dram_trans * TRANS_BYTES / DRAM_BW;
    Evaluation {
        dynamic_energy,
        leakage_energy,
        dram_energy,
        cache_time,
        dram_time,
    }
}

/// [`evaluate`] with the banked-DRAM observation of a
/// [`crate::membackend::DramModel`] run: the flat bandwidth/flat-energy
/// DRAM term is replaced by row-class latencies and energies from the
/// model's counters, a queue penalty for bank imbalance, the card's
/// per-access read/write energies (the NVM-DIMM knobs), and its
/// background (refresh/standby) power integrated over the workload's
/// total runtime. The cache-side terms are identical to [`evaluate`],
/// and an all-zero `dram` (a fixed-latency run) falls back to it
/// exactly, so LLC-only results are unchanged.
///
/// The background-power term makes the DRAM energy
/// technology-dependent even at iso-capacity (where the miss streams
/// are identical): a slower cache keeps the DIMM powered longer.
pub fn evaluate_with_dram(
    ppa: &CachePpa,
    stats: &MemStats,
    dram: &DramStats,
    card: &DramConfig,
) -> Evaluation {
    let base = evaluate(ppa, stats);
    if dram.accesses() == 0 {
        return base;
    }
    // Row-class service time, serialized per channel (ideal channel
    // parallelism), plus one column access of wait per queued line —
    // per-bank occupancy beyond the fair share (FR-FCFS approximation).
    let service = dram.row_hits as f64 * card.t_row_hit
        + dram.row_misses as f64 * card.t_row_miss
        + dram.row_conflicts as f64 * card.t_row_conflict;
    let dram_time =
        service / f64::from(card.channels) + dram.queue_excess() as f64 * card.t_row_hit;
    let access_energy = dram.row_hits as f64 * card.e_row_hit
        + dram.row_misses as f64 * card.e_row_miss
        + dram.row_conflicts as f64 * card.e_row_conflict
        + dram.reads as f64 * card.e_read
        + dram.writes as f64 * card.e_write;
    let dram_energy = access_energy + card.leakage_w * (base.cache_time + dram_time);
    Evaluation {
        dram_energy,
        dram_time,
        ..base
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::bitcell::BitcellKind;
    use crate::nvsim::optimizer::tuned_cache;
    use crate::workloads::profiler::{profile_suite, PROFILE_L2};
    use crate::util::units::MB;

    fn eval_suite(kind: BitcellKind) -> Vec<Evaluation> {
        let ppa = tuned_cache(kind, 3 * MB).ppa;
        profile_suite(PROFILE_L2)
            .iter()
            .map(|p| evaluate(&ppa, &p.stats))
            .collect()
    }

    #[test]
    fn latencies_quantize_up_to_cycles() {
        let cycle = 1.0 / L2_CLOCK_HZ;
        assert!((to_cycles_latency(cycle * 2.2) - 3.0 * cycle).abs() < 1e-15);
        assert!((to_cycles_latency(cycle * 3.0) - 3.0 * cycle).abs() < 1e-15);
    }

    #[test]
    fn sram_leakage_dominates_its_total_energy() {
        // The paper's central observation behind Fig 5.
        for e in eval_suite(BitcellKind::Sram) {
            assert!(e.leakage_energy > e.dynamic_energy);
        }
    }

    #[test]
    fn stt_dynamic_energy_exceeds_sram() {
        // Fig 4: STT ~2.2× SRAM dynamic energy on average.
        let sram = eval_suite(BitcellKind::Sram);
        let stt = eval_suite(BitcellKind::SttMram);
        let ratios: Vec<f64> = sram
            .iter()
            .zip(&stt)
            .map(|(s, t)| t.dynamic_energy / s.dynamic_energy)
            .collect();
        let mean = crate::util::stats::mean(&ratios);
        assert!((1.5..3.0).contains(&mean), "mean STT dyn ratio {mean}");
    }

    #[test]
    fn mram_leakage_energy_is_far_lower() {
        // Fig 4 bottom: 6.3× (STT) and 10× (SOT) lower on average.
        let sram = eval_suite(BitcellKind::Sram);
        let stt = eval_suite(BitcellKind::SttMram);
        let sot = eval_suite(BitcellKind::SotMram);
        let mean_ratio = |xs: &[Evaluation]| {
            let r: Vec<f64> = sram
                .iter()
                .zip(xs)
                .map(|(s, m)| s.leakage_energy / m.leakage_energy)
                .collect();
            crate::util::stats::mean(&r)
        };
        let stt_r = mean_ratio(&stt);
        let sot_r = mean_ratio(&sot);
        assert!((4.5..9.0).contains(&stt_r), "STT leak advantage {stt_r}");
        assert!((7.5..14.0).contains(&sot_r), "SOT leak advantage {sot_r}");
        assert!(sot_r > stt_r);
    }

    #[test]
    fn edp_with_dram_exceeds_cache_edp() {
        for e in eval_suite(BitcellKind::SotMram) {
            assert!(e.edp_with_dram() > e.edp_cache());
            assert!(e.total_energy() > e.cache_energy());
        }
    }

    #[test]
    fn rel_rollup_handles_idle_arrays_and_scales_with_wear() {
        let rel = RelSpec::stt_default();
        let mut sim = SimResult {
            l2_bytes: 0,
            l2_accesses: 0,
            l2_hits: 0,
            l2_misses: 0,
            writebacks: 0,
            l2_write_hits: 0,
            l2_write_misses: 0,
            l2_array_writes: 0,
            dram_fills: 0,
            dram_writes: 0,
            warmup_accesses: 0,
            faults_corrected: 0,
            faults_detected: 0,
            faults_silent: 0,
            retired_ways: 0,
            max_line_writes: 0,
            dram: DramStats::default(),
            l1: None,
        };
        let idle = rel_from_sim(&rel, &sim, 1024, 1.0);
        assert_eq!(idle.uber, 0.0, "no bits read, no error rate");
        assert!(idle.lifetime_years.is_infinite(), "an idle array never wears out");
        sim.l2_accesses = 1000;
        sim.faults_silent = 2;
        sim.faults_corrected = 7;
        sim.max_line_writes = 100;
        let r = rel_from_sim(&rel, &sim, 1024, 2.0);
        assert!((r.uber - 2.0 / (1000.0 * 1024.0)).abs() < 1e-12 * r.uber, "uber {}", r.uber);
        let expect = rel.endurance_cycles / 100.0 * 2.0 / SECONDS_PER_YEAR;
        assert!(
            (r.lifetime_years - expect).abs() < 1e-9 * expect,
            "lifetime {} vs {expect}",
            r.lifetime_years
        );
        assert_eq!((r.corrected, r.silent), (7, 2));
        // Doubling the wear rate halves the lifetime.
        sim.max_line_writes = 200;
        let faster = rel_from_sim(&rel, &sim, 1024, 2.0);
        assert!((faster.lifetime_years - expect / 2.0).abs() < 1e-9 * expect);
    }

    #[test]
    fn dram_rollup_is_nonzero_and_technology_dependent() {
        use crate::gpusim::{net_trace, simulate_backend, CacheConfig, GpuConfig};
        use crate::membackend::MemBackendConfig;
        use crate::workloads::nets;
        let gpu = GpuConfig::gtx_1080_ti();
        let card = DramConfig::default();
        let sim = simulate_backend(
            net_trace(&nets::squeezenet(), 1),
            &gpu,
            CacheConfig::default(),
            0,
            8,
            &MemBackendConfig::Dram(card),
        );
        let stats = stats_from_sim(&sim, gpu.l2_line);
        let sram = tuned_cache(BitcellKind::Sram, 3 * MB).ppa;
        let sot = tuned_cache(BitcellKind::SotMram, 3 * MB).ppa;
        let a = evaluate_with_dram(&sram, &stats, &sim.dram, &card);
        let b = evaluate_with_dram(&sot, &stats, &sim.dram, &card);
        assert!(a.dram_energy > 0.0 && a.dram_time > 0.0);
        // Same miss stream, different cache time: the background-power
        // term makes the DRAM energy differ across technologies.
        assert_ne!(a.cache_time, b.cache_time);
        assert_ne!(a.dram_energy, b.dram_energy);
        // Cache-side terms are evaluate()'s, to the bit.
        let flat = evaluate(&sram, &stats);
        assert_eq!(a.dynamic_energy, flat.dynamic_energy);
        assert_eq!(a.leakage_energy, flat.leakage_energy);
        assert_eq!(a.cache_time, flat.cache_time);
        // An all-zero observation (fixed-latency run) falls back exactly.
        let zero = evaluate_with_dram(&sram, &stats, &DramStats::default(), &card);
        assert_eq!(zero.dram_energy, flat.dram_energy);
        assert_eq!(zero.dram_time, flat.dram_time);
    }

    #[test]
    fn sim_counters_convert_to_sector_transactions() {
        use crate::gpusim::{simulate, simulate_config, CacheConfig, GpuConfig, WritePolicy};
        use crate::gpusim::net_trace;
        use crate::workloads::nets;
        let net = nets::squeezenet();
        let gpu = GpuConfig::gtx_1080_ti();
        let sim = simulate(net_trace(&net, 1), &gpu);
        let wb = stats_from_sim(&sim, gpu.l2_line);
        // 128B lines → 4 sectors per access; read dominance carries over.
        assert!(wb.l2_reads % 4 == 0 && wb.l2_reads > wb.l2_writes);
        assert_eq!(wb.dram_reads + wb.dram_writes, 4 * sim.dram_accesses());
        // Bypass: fewer (NVM) cache writes; the offered read stream is
        // policy-invariant.
        let cfg = CacheConfig { write: WritePolicy::WriteBypass, ..CacheConfig::default() };
        let byp = stats_from_sim(&simulate_config(net_trace(&net, 1), &gpu, cfg, 0), gpu.l2_line);
        assert!(byp.l2_writes < wb.l2_writes);
        assert_eq!(byp.l2_reads, wb.l2_reads);
        // Write-through: every write reaches DRAM — strictly more DRAM
        // write traffic than write-back's eviction stream.
        let wt = CacheConfig { write: WritePolicy::WriteThrough, ..CacheConfig::default() };
        let wt = stats_from_sim(&simulate_config(net_trace(&net, 1), &gpu, wt, 0), gpu.l2_line);
        assert!(wt.dram_writes > wb.dram_writes);
        assert_eq!(wt.l2_writes, byp.l2_writes, "both charge only write hits to the array");
    }
}
