//! Quickstart: the DeepNVM++ pipeline in ~40 lines.
//!
//! Characterizes the three bitcells (Table 1), EDAP-tunes a 3MB cache for
//! each (Table 2's iso-capacity columns), and evaluates one workload
//! (AlexNet inference) on all three — the paper's core loop.
//!
//! Run: `cargo run --release --example quickstart`

use deepnvm::analysis::evaluate;
use deepnvm::device::bitcell::BitcellKind;
use deepnvm::nvsim::optimizer::tuned_cache;
use deepnvm::util::table::{fnum, Table};
use deepnvm::util::units::{to_mm2, to_mw, to_nj, to_ns, MB};
use deepnvm::workloads::memstats::Phase;
use deepnvm::workloads::profiler::{profile, Workload, PROFILE_L2};

fn main() {
    // 1. Device + cache layers: EDAP-tuned 3MB L2 per technology.
    let mut t = Table::new(
        "EDAP-tuned 3MB L2 caches",
        &["tech", "RL (ns)", "WL (ns)", "RE (nJ)", "WE (nJ)", "leak (mW)", "area (mm2)"],
    );
    let mut caches = Vec::new();
    for kind in BitcellKind::ALL {
        let c = tuned_cache(kind, 3 * MB);
        t.row(&[
            kind.name().into(),
            fnum(to_ns(c.ppa.read_latency), 2),
            fnum(to_ns(c.ppa.write_latency), 2),
            fnum(to_nj(c.ppa.read_energy), 3),
            fnum(to_nj(c.ppa.write_energy), 3),
            fnum(to_mw(c.ppa.leakage_power), 0),
            fnum(to_mm2(c.ppa.area), 2),
        ]);
        caches.push(c.ppa);
    }
    println!("{}", t.render());

    // 2. Workload layer: profile AlexNet inference (batch 4, per paper).
    let alexnet = Workload::net("alexnet", Phase::Inference);
    let stats = profile(&alexnet, 4, PROFILE_L2).expect("alexnet is builtin").stats;
    println!(
        "AlexNet-I memory statistics: {} L2 reads, {} L2 writes (R/W {:.2})\n",
        stats.l2_reads,
        stats.l2_writes,
        stats.rw_ratio()
    );

    // 3. Cross-layer roll-up: energy/EDP per technology.
    let mut t = Table::new(
        "AlexNet-I on each technology (3MB L2)",
        &["tech", "cache energy (mJ)", "EDP vs SRAM"],
    );
    let base = evaluate(&caches[0], &stats).edp_with_dram();
    for (kind, ppa) in BitcellKind::ALL.iter().zip(&caches) {
        let e = evaluate(ppa, &stats);
        t.row(&[
            kind.name().into(),
            fnum(e.cache_energy() * 1e3, 1),
            fnum(e.edp_with_dram() / base, 3),
        ]);
    }
    println!("{}", t.render());
    println!("Next: `repro list` for every paper table/figure generator.");
}
