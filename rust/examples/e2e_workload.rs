//! End-to-end driver: all layers composed on a real small workload.
//!
//! 1. Loads the AOT-compiled JAX/Pallas CNN training artifact
//!    (`artifacts/cnn_train.hlo.txt`, built by `make artifacts`) into the
//!    rust PJRT runtime — Python is NOT running here.
//! 2. Trains the CNN for a few hundred SGD steps on synthetic data
//!    (separable class blobs) and logs the loss curve.
//! 3. Describes the same CNN to the workload layer, profiles its memory
//!    behaviour, pushes its address trace through the GPGPU-Sim
//!    substitute, and reports the paper's headline metric — EDP vs SRAM —
//!    for STT-MRAM and SOT-MRAM L2 caches running *this* workload.
//!
//! Run: `make artifacts && cargo run --release --example e2e_workload`

use deepnvm::analysis::evaluate;
use deepnvm::device::bitcell::BitcellKind;
use deepnvm::gpusim::{capacity_sweep, net_trace};
use deepnvm::nvsim::optimizer::tuned_cache;
use deepnvm::runtime::{Runtime, TensorF32};
use deepnvm::util::rng::Rng;
use deepnvm::util::table::{fnum, Table};
use deepnvm::util::units::MB;
use deepnvm::workloads::ir::{NetBuilder, Shape};
use deepnvm::workloads::memstats::{net_stats, Phase};

const BATCH: usize = 32; // must match aot.py --batch
const IMAGE: usize = 16;
const CLASSES: usize = 10;
const STEPS: usize = 300;

/// Parameter shapes, mirroring python/compile/model.py::param_shapes().
fn param_shapes() -> Vec<Vec<i64>> {
    vec![
        vec![3, 3, 1, 8],
        vec![8],
        vec![3, 3, 8, 16],
        vec![16],
        vec![6 * 6 * 16, CLASSES as i64],
        vec![CLASSES as i64],
    ]
}

fn he_init(rng: &mut Rng, dims: &[i64]) -> TensorF32 {
    let numel: i64 = dims.iter().product();
    if dims.len() == 1 {
        return TensorF32::zeros(dims.to_vec());
    }
    let fan_in: i64 = dims[..dims.len() - 1].iter().product();
    let scale = (2.0 / fan_in as f64).sqrt();
    let data = (0..numel)
        .map(|_| {
            // Box-Muller from the deterministic PRNG.
            let u1 = rng.f64().max(1e-12);
            let u2 = rng.f64();
            ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos() * scale) as f32
        })
        .collect();
    TensorF32::new(dims.to_vec(), data)
}

/// Synthetic separable data: class k gets a blob at a class-specific
/// location; labels one-hot.
fn synth_batch(rng: &mut Rng) -> (TensorF32, TensorF32) {
    let mut x = vec![0.0f32; BATCH * IMAGE * IMAGE];
    let mut y = vec![0.0f32; BATCH * CLASSES];
    for b in 0..BATCH {
        let class = rng.usize_in(0, CLASSES);
        y[b * CLASSES + class] = 1.0;
        let (cy, cx) = (2 + (class / 5) * 8, 2 + (class % 5) * 2);
        for dy in 0..4 {
            for dx in 0..4 {
                let noise = (rng.f64() * 0.4) as f32;
                x[b * IMAGE * IMAGE + (cy + dy) * IMAGE + (cx + dx)] = 1.0 + noise;
            }
        }
        for p in 0..IMAGE * IMAGE {
            x[b * IMAGE * IMAGE + p] += (rng.f64() * 0.1) as f32;
        }
    }
    (
        TensorF32::new(vec![BATCH as i64, IMAGE as i64, IMAGE as i64, 1], x),
        TensorF32::new(vec![BATCH as i64, CLASSES as i64], y),
    )
}

fn main() -> deepnvm::Result<()> {
    // --- Layer check: artifacts present? ---
    let artifact = "artifacts/cnn_train.hlo.txt";
    if !std::path::Path::new(artifact).exists() {
        eprintln!("missing {artifact}; run `make artifacts` first");
        std::process::exit(2);
    }

    // --- 1. PJRT runtime: load + compile the training step ---
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let train = rt.load(artifact)?;
    println!("compiled {artifact}");

    // --- 2. Train: a few hundred SGD steps on synthetic data ---
    let mut rng = Rng::new(42);
    let mut params: Vec<TensorF32> = param_shapes().iter().map(|s| he_init(&mut rng, s)).collect();
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    let t0 = std::time::Instant::now();
    for step in 0..STEPS {
        let (x, y) = synth_batch(&mut rng);
        let mut inputs = params.clone();
        inputs.push(x);
        inputs.push(y);
        let outputs = train.run(&inputs)?;
        last_loss = outputs.last().unwrap().data[0];
        params = outputs[..outputs.len() - 1].to_vec();
        if first_loss.is_none() {
            first_loss = Some(last_loss);
        }
        if step % 50 == 0 || step == STEPS - 1 {
            println!("step {step:>4}  loss {last_loss:.4}");
        }
    }
    let first = first_loss.unwrap();
    println!(
        "trained {STEPS} steps in {:.1}s: loss {first:.4} -> {last_loss:.4}",
        t0.elapsed().as_secs_f64()
    );
    assert!(
        last_loss < first * 0.5,
        "training must reduce loss ({first} -> {last_loss})"
    );

    // --- 3. Cross-layer analysis of this exact workload ---
    let cnn = NetBuilder::new("mini_cnn", "MiniCNN", Shape::new(1, IMAGE as u64, IMAGE as u64))
        .conv("conv1", 8, 3, 1, 0)
        .conv("conv2", 16, 3, 1, 0)
        .pool("pool", 2, 2, 0)
        .fc("fc", CLASSES as u64)
        .build();
    let stats = net_stats(&cnn, Phase::Training, BATCH as u64, 3 * MB);
    println!(
        "\nMiniCNN-T memory statistics: {} L2 reads / {} writes (R/W {:.2})",
        stats.l2_reads,
        stats.l2_writes,
        stats.rw_ratio()
    );

    // GPGPU-Sim substitute on the same network: the whole capacity sweep
    // is one pass over the streamed trace.
    let sweep = capacity_sweep(net_trace(&cnn, BATCH as u64), &[7 * MB, 10 * MB]);
    for p in &sweep[1..] {
        println!(
            "  L2 {}MB: DRAM accesses {} ({:+.1}% vs 3MB)",
            p.result.l2_bytes / MB,
            p.result.dram_accesses(),
            -p.dram_reduction_pct
        );
    }

    // Headline metric for this workload.
    let mut t = Table::new(
        "MiniCNN training: EDP vs SRAM (3MB iso-capacity)",
        &["tech", "EDP (norm)", "reduction"],
    );
    let base = evaluate(&tuned_cache(BitcellKind::Sram, 3 * MB).ppa, &stats).edp_with_dram();
    for kind in [BitcellKind::SttMram, BitcellKind::SotMram] {
        let e = evaluate(&tuned_cache(kind, 3 * MB).ppa, &stats).edp_with_dram();
        t.row(&[
            kind.name().into(),
            fnum(e / base, 3),
            format!("{:.2}x", base / e),
        ]);
    }
    println!("\n{}", t.render());
    println!("e2e OK: PJRT training + profiling + simulation + roll-up all composed.");
    Ok(())
}
