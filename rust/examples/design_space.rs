//! Design-space exploration beyond the paper: "DeepNVM++ ... can be used
//! for the characterization, modeling, and analysis of ANY NVM
//! technology". This example defines a hypothetical next-generation SOT
//! device (lower critical currents, faster τ0 — the trajectory the
//! paper's §5 projects as fabrication matures) purely as a `TechSpec`
//! descriptor, registers it with the query engine, and answers one batch
//! of typed queries: all four technologies, EDAP-tuned at 8MB, rolled up
//! on VGG-16 training — no bespoke pipeline code, and the same descriptor
//! could equally come from a `.tech` file via `--tech-file`.
//!
//! Run: `cargo run --release --example design_space`

use deepnvm::engine::{descriptor, Engine, Query, TechSpec};
use deepnvm::util::table::{fnum, Table};
use deepnvm::util::units::{to_mm2, to_mw, to_ns, MB};
use deepnvm::workloads::memstats::Phase;
use deepnvm::workloads::profiler::Workload;

/// A projected next-gen SOT stack: ~35% lower critical currents (better
/// spin-Hall efficiency) and a faster characteristic time. Everything
/// else inherits today's SOT calibration.
fn nextgen_sot() -> TechSpec {
    let mut spec = TechSpec::sot();
    spec.id = "sot_nextgen".into();
    spec.name = "SOT (next-gen)".into();
    let mtj = spec.mtj.as_mut().expect("sot is mram-class");
    mtj.ic_set = 78.0e-6;
    mtj.ic_reset = 72.0e-6;
    mtj.tau0 = 60.0e-12;
    mtj.r_rail = 500.0;
    spec
}

fn main() {
    let engine = Engine::new();
    let custom = nextgen_sot();
    println!("--- descriptor (save as nextgen.tech and pass via --tech-file) ---");
    println!("{}", descriptor::serialize(&custom));
    engine.register(custom).expect("fresh id");

    // The §3.1 characterization runs from the descriptor alone: the fin
    // sweep re-optimizes for the lower critical currents.
    let cell = engine.bitcell("sot_nextgen").expect("characterizes");
    println!(
        "next-gen SOT bitcell: {} write fins chosen, write {:.0}/{:.0} ps, {:.3}/{:.3} pJ, rel. area {:.2}\n",
        cell.write_fins,
        cell.write_latency_set * 1e12,
        cell.write_latency_reset * 1e12,
        cell.write_energy_set * 1e12,
        cell.write_energy_reset * 1e12,
        cell.area_rel_sram()
    );

    // One typed query per technology; the engine tunes + profiles + rolls
    // up each through the shared thread pool.
    let cap = 8 * MB;
    let vgg_training = Workload::Dnn { index: 2, phase: Phase::Training };
    let queries: Vec<Query> = ["sram", "stt", "sot", "sot_nextgen"]
        .iter()
        .map(|tech| Query::tune(*tech, cap).with_workload(vgg_training))
        .collect();
    let evals: Vec<_> = engine
        .evaluate_many(&queries)
        .into_iter()
        .map(|r| r.expect("registered tech at a valid capacity"))
        .collect();

    let base = evals[0].workload.as_ref().unwrap().rollup.edp_with_dram();
    let mut t = Table::new(
        "8MB L2 design space (VGG-16 training EDP, normalized to SRAM)",
        &["tech", "RL (ns)", "WL (ns)", "leak (mW)", "area (mm2)", "EDP (norm)"],
    );
    for ev in &evals {
        let name = engine.tech(&ev.tech).expect("registered").name.clone();
        let ppa = &ev.design.ppa;
        let edp = ev.workload.as_ref().unwrap().rollup.edp_with_dram();
        t.row(&[
            name,
            fnum(to_ns(ppa.read_latency), 2),
            fnum(to_ns(ppa.write_latency), 2),
            fnum(to_mw(ppa.leakage_power), 0),
            fnum(to_mm2(ppa.area), 2),
            fnum(edp / base, 3),
        ]);
    }
    println!("{}", t.render());
    let s = engine.stats();
    println!("engine cache this run: {}", s.summary());
    println!("The framework extends to arbitrary NVM devices: edit the descriptor, rerun.");
}
