//! Design-space exploration beyond the paper: "DeepNVM++ ... can be used
//! for the characterization, modeling, and analysis of ANY NVM
//! technology". This example injects a hypothetical next-generation SOT
//! device (lower critical current, faster τ0 — the trajectory the
//! paper's §5 projects as fabrication matures) and re-runs the whole
//! pipeline: transient characterization → EDAP cache tuning → workload
//! EDP, comparing it against today's three technologies at 8MB.
//!
//! Run: `cargo run --release --example design_space`

use deepnvm::analysis::evaluate;
use deepnvm::device::bitcell::{BitcellKind, BitcellParams};
use deepnvm::device::circuit::{pulse_to_failure, simulate_sense, simulate_write};
use deepnvm::device::finfet::{Corner, FinFet};
use deepnvm::device::mtj::{Mtj, MtjKind, WriteDir};
use deepnvm::device::characterize::cal;
use deepnvm::nvsim::cache::{cache_ppa, AccessType};
use deepnvm::nvsim::geometry::enumerate;
use deepnvm::nvsim::optimizer::tuned_cache;
use deepnvm::nvsim::tech::SIZING_TARGETS;
use deepnvm::util::table::{fnum, Table};
use deepnvm::util::units::{to_mm2, to_mw, to_ns, MB};
use deepnvm::workloads::memstats::Phase;
use deepnvm::workloads::profiler::{profile, Workload};

/// A projected next-gen SOT stack: 35% lower critical currents (better
/// spin-Hall efficiency) and a faster characteristic time.
fn nextgen_sot() -> Mtj {
    Mtj {
        kind: MtjKind::Sot,
        r_p: 4_000.0,
        r_ap: 8_000.0,
        ic_set: 78.0e-6,
        ic_reset: 72.0e-6,
        tau0: 60.0e-12,
        r_rail: 500.0,
    }
}

/// Characterize the custom device with the same §3.1 procedure (2 write
/// fins suffice at the lower Ic — area shrinks further).
fn characterize_nextgen() -> BitcellParams {
    let mtj = nextgen_sot();
    let wf = 2;
    let access = FinFet::nmos(wf, Corner::WorstDelay);
    let t_set = pulse_to_failure(&access, &mtj, WriteDir::Set, 1e-12, 50e-9, 1.0)
        .expect("next-gen SOT must switch with 2 fins");
    let t_reset = pulse_to_failure(&access, &mtj, WriteDir::Reset, 1e-12, 50e-9, 1.0).unwrap();
    let wp = FinFet::nmos(wf, Corner::WorstPower);
    let e_set = simulate_write(&wp, &mtj, WriteDir::Set, t_set, 1.0).loop_energy * 1.48;
    let e_reset = simulate_write(&wp, &mtj, WriteDir::Reset, t_reset, 1.0).loop_energy * 1.91;
    let read = FinFet::nmos(1, Corner::WorstDelay);
    let sense = simulate_sense(
        cal::C_BITLINE_SOT,
        cal::V_READ_SOT,
        read.ron(),
        mtj.r_p,
        mtj.r_ap,
        cal::T_SA,
    );
    BitcellParams {
        kind: BitcellKind::SotMram, // cache model treats it as the SOT family
        sense_latency: sense.t_sense,
        sense_energy: sense.energy + 0.99 * cal::C_BITLINE_SOT * 0.64,
        write_latency_set: t_set,
        write_latency_reset: t_reset,
        write_energy_set: e_set,
        write_energy_reset: e_reset,
        write_fins: wf,
        read_fins: 1,
        area: deepnvm::device::bitcell::sot_cell_area(wf, 1),
        cell_leakage: 0.0,
    }
}

fn main() {
    let cap = 8 * MB;
    let custom = characterize_nextgen();
    println!(
        "next-gen SOT bitcell: write {:.0}/{:.0} ps, {:.3}/{:.3} pJ, rel. area {:.2}\n",
        custom.write_latency_set * 1e12,
        custom.write_latency_reset * 1e12,
        custom.write_energy_set * 1e12,
        custom.write_energy_reset * 1e12,
        custom.area_rel_sram()
    );

    // EDAP-tune a cache from the custom bitcell (Algorithm 1, inlined).
    let mut best = None;
    for org in enumerate(cap) {
        for access in AccessType::ALL {
            for &sizing in SIZING_TARGETS.iter() {
                let ppa = cache_ppa(&custom, &org, access, sizing);
                if best
                    .map(|b: deepnvm::nvsim::cache::CachePpa| ppa.edap() < b.edap())
                    .unwrap_or(true)
                {
                    best = Some(ppa);
                }
            }
        }
    }
    let custom_cache = best.unwrap();

    let mut t = Table::new(
        "8MB L2 design space (VGG-16 training EDP, normalized to SRAM)",
        &["tech", "RL (ns)", "WL (ns)", "leak (mW)", "area (mm2)", "EDP (norm)"],
    );
    let vgg = Workload::Dnn { index: 2, phase: Phase::Training };
    let stats = profile(vgg, 64, cap).stats;
    let sram = tuned_cache(BitcellKind::Sram, cap).ppa;
    let base = evaluate(&sram, &stats).edp_with_dram();
    let mut row = |name: &str, ppa: &deepnvm::nvsim::cache::CachePpa| {
        let e = evaluate(ppa, &stats).edp_with_dram();
        t.row(&[
            name.into(),
            fnum(to_ns(ppa.read_latency), 2),
            fnum(to_ns(ppa.write_latency), 2),
            fnum(to_mw(ppa.leakage_power), 0),
            fnum(to_mm2(ppa.area), 2),
            fnum(e / base, 3),
        ]);
    };
    row("SRAM", &sram);
    row("STT-MRAM", &tuned_cache(BitcellKind::SttMram, cap).ppa);
    row("SOT-MRAM", &tuned_cache(BitcellKind::SotMram, cap).ppa);
    row("SOT (next-gen)", &custom_cache);
    println!("{}", t.render());
    println!("The framework extends to arbitrary NVM devices: swap the MTJ card, rerun.");
}
