//! Design-space exploration beyond the paper: "DeepNVM++ ... can be used
//! for the characterization, modeling, and analysis of ANY NVM
//! technology". Instead of evaluating one hand-picked next-generation
//! device, this example *searches* the fabrication-maturity trajectory
//! the paper's §5 projects for SOT-MRAM: a three-axis space over critical
//! switching current (spin-Hall efficiency improving), characteristic
//! switching time τ0, and cache capacity. Every (ic_set, τ0) point
//! materializes as a derived technology descriptor registered with the
//! query engine on demand; the grid fans through `Engine::evaluate_many`;
//! and the exact Pareto frontier over (EDP, area) with its knee point
//! falls out — the same machinery behind `repro explore`.
//!
//! Run: `cargo run --release --example design_space`

use deepnvm::engine::{Engine, TechSpec};
use deepnvm::explore::{self, Objective, SearchConfig, Space, Strategy};
use deepnvm::workloads::memstats::Phase;
use deepnvm::workloads::profiler::Workload;

fn main() {
    let engine = Engine::new();

    // Anchor the axes on today's calibrated SOT stack so every swept
    // point is a plausible maturation of it (lower critical currents are
    // *easier* writes — the sweep stays inside the feasible fin range).
    let base = TechSpec::sot();
    let mtj = base.mtj.expect("sot is mram-class");
    let space = Space::new()
        .tech(["sot"])
        .capacity_mb([2, 4, 8])
        .spec_axis("mtj.ic_set", [mtj.ic_set, 0.8 * mtj.ic_set, 0.65 * mtj.ic_set])
        .spec_axis("mtj.tau0", [mtj.tau0, 0.6 * mtj.tau0])
        .workload([Workload::net("vgg16", Phase::Training)]); // VGG-16-T

    println!("--- equivalent [space] section (save in a .tech file for `repro explore`) ---");
    println!("[space]");
    println!("tech = sot");
    println!("capacity_mb = 2, 4, 8");
    println!("mtj.ic_set = {}, {}, {}", mtj.ic_set, 0.8 * mtj.ic_set, 0.65 * mtj.ic_set);
    println!("mtj.tau0 = {}, {}", mtj.tau0, 0.6 * mtj.tau0);
    println!("workload = vgg16-t\n");

    let cfg = SearchConfig { strategy: Strategy::Grid, budget: 64, seed: 7 };
    let result = explore::run(&engine, &space, &[Objective::Edp, Objective::Area], &cfg)
        .expect("space is valid");

    print!("{}", result.render());
    println!(
        "{} of {} grid points evaluated; {} derived technologies registered on demand.",
        result.outcome.evaluated.len(),
        result.outcome.space_size,
        engine.techs().len() - 3,
    );
    println!("The framework extends to arbitrary NVM devices: edit the axes, rerun.");
}
