//! Iso-capacity study (paper §4.1): regenerate Figs 4–6 and print the
//! headline paper-vs-measured comparison.
//!
//! Run: `cargo run --release --example iso_capacity_study`

use deepnvm::coordinator::{run_one, RunnerConfig};
use deepnvm::engine::Engine;
use deepnvm::experiments::Params;

fn main() {
    let cfg = RunnerConfig::default();
    for id in ["fig4", "fig5", "fig6"] {
        let report = run_one(Engine::shared(), id, &Params::default(), &cfg)
            .expect("registered experiment");
        for h in &report.headlines {
            eprintln!("HEADLINE {h}");
        }
    }
    eprintln!("series CSVs written under results/");
}
