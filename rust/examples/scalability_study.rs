//! Scalability study (paper §4.3): regenerate Figs 10–13 — every
//! technology EDAP-tuned at 1..32MB, workload suite evaluated per point.
//!
//! Run: `cargo run --release --example scalability_study`

use deepnvm::coordinator::{run_one, RunnerConfig};
use deepnvm::engine::Engine;
use deepnvm::experiments::Params;

fn main() {
    let cfg = RunnerConfig::default();
    for id in ["fig10", "fig11", "fig12", "fig13"] {
        let report = run_one(Engine::shared(), id, &Params::default(), &cfg)
            .expect("registered experiment");
        for h in &report.headlines {
            eprintln!("HEADLINE {h}");
        }
    }
    eprintln!("series CSVs written under results/");
}
