//! Scalability study (paper §4.3): regenerate Figs 10–13 — every
//! technology EDAP-tuned at 1..32MB, workload suite evaluated per point.
//!
//! Run: `cargo run --release --example scalability_study`

use deepnvm::coordinator::{run_one, RunnerConfig};

fn main() {
    let cfg = RunnerConfig::default();
    for id in ["fig10", "fig11", "fig12", "fig13"] {
        let report = run_one(id, &cfg).expect("registered experiment");
        for h in &report.headlines {
            eprintln!("HEADLINE {h}");
        }
    }
    eprintln!("series CSVs written under results/");
}
